"""Serving substrate tests: engine generation + bandit scheduler routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.engine import Engine, sample_token
from repro.serving.scheduler import ArmSpec, BanditScheduler, Request


def _engine(arch="qwen1.5-0.5b", seed=0):
    cfg = get_config(arch).reduced()
    params = jax.tree.map(lambda x: x,  # materialize
                          __import__("repro.models.registry",
                                     fromlist=["registry"]).init_params(
                              cfg, jax.random.PRNGKey(seed)))
    return cfg, Engine(cfg, params, cache_len=64)


def test_sample_token_greedy_and_temp():
    logits = jnp.asarray([[[0.1, 5.0, 0.2]]])
    assert int(sample_token(logits, jax.random.PRNGKey(0))[0, 0]) == 1
    tok = sample_token(logits, jax.random.PRNGKey(0), temperature=1.0)
    assert tok.shape == (1, 1)


def test_engine_generates_fixed_length():
    cfg, eng = _engine()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out = eng.generate({"tokens": toks}, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()


def test_engine_greedy_deterministic():
    cfg, eng = _engine()
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0,
                              cfg.vocab_size)
    a = np.asarray(eng.generate({"tokens": toks}, 4))
    b = np.asarray(eng.generate({"tokens": toks}, 4))
    np.testing.assert_array_equal(a, b)


def test_scheduler_kernel_path_matches_reference():
    """use_kernels=True (deprecated Pallas spelling) routes identically."""
    cfg, eng = _engine(seed=0)
    _, eng1 = _engine(seed=1)
    arms = [ArmSpec("a", eng, 1e-5), ArmSpec("b", eng1, 1e-4)]
    ref = BanditScheduler(arms, dim=32)
    with pytest.deprecated_call():
        ker = BanditScheduler(arms, dim=32, use_kernels=True)
    rng = np.random.default_rng(1)
    for i in range(10):
        ctx = rng.standard_normal(32).astype(np.float32)
        r, a = float(rng.random() < 0.5), int(rng.integers(0, 2))
        ref.feedback(a, ctx, r)
        ker.feedback(a, ctx, r)
    ctxs = rng.standard_normal((5, 32)).astype(np.float32)
    np.testing.assert_array_equal(ref.route(ctxs), ker.route(ctxs))


def test_scheduler_backend_routing_matches_ref():
    """backend='pallas_interpret' (native block-layout kernels) selects
    the same arms as backend='ref' for identical feedback streams."""
    cfg, eng = _engine(seed=0)
    _, eng1 = _engine(seed=1)
    arms = [ArmSpec("a", eng, 1e-5), ArmSpec("b", eng1, 1e-4)]
    sref = BanditScheduler(arms, dim=32, backend="ref")
    sker = BanditScheduler(arms, dim=32, backend="pallas_interpret")
    rng = np.random.default_rng(2)
    for i in range(12):
        ctx = rng.standard_normal(32).astype(np.float32)
        r, a = float(rng.random() < 0.5), int(rng.integers(0, 2))
        sref.feedback(a, ctx, r)
        sker.feedback(a, ctx, r)
    ctxs = rng.standard_normal((6, 32)).astype(np.float32)
    np.testing.assert_array_equal(sref.route(ctxs), sker.route(ctxs))
    # states agree too (the kernel update path is the same math)
    np.testing.assert_allclose(np.asarray(sref.state.a_inv_t),
                               np.asarray(sker.state.a_inv_t),
                               atol=1e-4, rtol=1e-4)


def test_scheduler_rejects_unknown_backend():
    cfg, eng = _engine(seed=0)
    with pytest.raises(ValueError):
        BanditScheduler([ArmSpec("a", eng, 1e-5)], dim=16, backend="bogus")


def test_scheduler_budget_policy_opts_out():
    """budget_linucb routing consumes per-request budgets: once every
    arm's observed cost exceeds the remaining budget, route returns -1."""
    cfg, eng = _engine(seed=0)
    _, eng1 = _engine(seed=1)
    arms = [ArmSpec("cheap", eng, 1e-5), ArmSpec("pricey", eng1, 1e-4)]
    sched = BanditScheduler(arms, dim=16, policy="budget_linucb")
    rng = np.random.default_rng(3)
    ctx = rng.standard_normal(16).astype(np.float32)
    for a in (0, 1):
        for _ in range(40):
            sched.feedback(a, ctx, 1.0, cost=0.5)
    out = sched.route(ctx[None], remaining=np.asarray([1e-6], np.float32))
    assert out[0] == -1
    ok = sched.route(ctx[None], remaining=np.asarray([10.0], np.float32))
    assert ok[0] >= 0


def test_scheduler_routes_and_learns():
    """Feedback favouring arm 1 for a context direction must shift routing
    toward arm 1 for that direction."""
    cfg, eng0 = _engine(seed=0)
    _, eng1 = _engine(seed=1)
    sched = BanditScheduler(
        [ArmSpec("small", eng0, 1e-5), ArmSpec("large", eng1, 1e-4)],
        dim=16, alpha=0.3)
    rng = np.random.default_rng(0)
    ctx = rng.uniform(0, 1, 16).astype(np.float32)
    ctx /= np.linalg.norm(ctx)
    for _ in range(30):
        sched.feedback(1, ctx, 1.0)
        sched.feedback(0, ctx, 0.0)
    assert sched.route(ctx[None])[0] == 1

    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                              cfg.vocab_size)
    reqs = [Request(uid=i, context=ctx,
                    batch={"tokens": toks}) for i in range(3)]
    resps = sched.serve(reqs)
    assert [r.uid for r in resps] == [0, 1, 2]
    assert all(r.arm == 1 for r in resps)
    assert all(r.cost > 0 and r.latency_s >= 0 for r in resps)


def test_use_kernels_deprecation_warning():
    """Regression: the deprecated use_kernels spelling must keep warning
    (and keep working — it pins the interpret backend on CPU) until it is
    removed. Engines are never touched at construction time, so dummy
    arms suffice."""
    arms = [ArmSpec("a", None, 1e-5), ArmSpec("b", None, 1e-4)]
    with pytest.warns(DeprecationWarning, match="use_kernels"):
        sched = BanditScheduler(arms, dim=8, use_kernels=True)
    assert sched._backend() == ("pallas" if jax.default_backend() == "tpu"
                                else "pallas_interpret")
    # use_kernels=False warns too but pins nothing
    with pytest.warns(DeprecationWarning):
        sched_off = BanditScheduler(arms, dim=8, use_kernels=False)
    assert sched_off._backend_override is None


def test_scheduler_feedback_batch_matches_sequential():
    """feedback_batch (the engine's multi-stream posterior fold) must
    agree with one feedback() call per observation."""
    arms = [ArmSpec("a", None, 1e-5), ArmSpec("b", None, 1e-4),
            ArmSpec("c", None, 2e-4)]
    batched = BanditScheduler(arms, dim=16)
    seq = BanditScheduler(arms, dim=16)
    rng = np.random.default_rng(5)
    xs = rng.standard_normal((8, 16)).astype(np.float32)
    sel = batched.route(xs)
    rs = (rng.random(8) < 0.5).astype(np.float32)
    cs = rng.random(8).astype(np.float32) * 1e-4
    batched.feedback_batch(sel, xs, rs, cs)
    for i in range(8):
        seq.feedback(int(sel[i]), xs[i], float(rs[i]), float(cs[i]))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3),
        batched.state, seq.state)
    np.testing.assert_array_equal(batched.route(xs), seq.route(xs))


def test_scheduler_feedback_batch_backend_parity():
    """The batch fold routes through the selected-block kernel under the
    pallas backend and must match the ref fold."""
    arms = [ArmSpec("a", None, 1e-5), ArmSpec("b", None, 1e-4)]
    sref = BanditScheduler(arms, dim=16, backend="ref")
    sker = BanditScheduler(arms, dim=16, backend="pallas_interpret")
    rng = np.random.default_rng(6)
    xs = rng.standard_normal((6, 16)).astype(np.float32)
    sel = sref.route(xs)
    rs = (rng.random(6) < 0.5).astype(np.float32)
    sref.feedback_batch(sel, xs, rs)
    sker.feedback_batch(sel, xs, rs)
    np.testing.assert_allclose(np.asarray(sref.state.a_inv_t),
                               np.asarray(sker.state.a_inv_t),
                               atol=1e-4, rtol=1e-4)


def test_scheduler_feedback_batch_budget_policy():
    """Budget states fold bandit stats + cost statistics in one dispatch."""
    arms = [ArmSpec("a", None, 1e-5), ArmSpec("b", None, 1e-4)]
    sched = BanditScheduler(arms, dim=16, policy="budget_linucb")
    rng = np.random.default_rng(7)
    xs = rng.standard_normal((5, 16)).astype(np.float32)
    sel = np.asarray([0, 1, 0, 0, 1], np.int32)
    sched.feedback_batch(sel, xs, np.ones(5, np.float32),
                         np.full(5, 1e-4, np.float32))
    np.testing.assert_allclose(np.asarray(sched.state.cost_count),
                               [3.0, 2.0])
    np.testing.assert_allclose(np.asarray(sched.state.cost_sum),
                               [3e-4, 2e-4], rtol=1e-5)
