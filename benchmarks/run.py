"""Benchmark harness entry point: one module per paper table/figure.

Table rows are spec-driven: each table module iterates a list of
``(EnvSpec, PolicySpec)`` pairs (``common.TABLE_CONFIGS`` /
``common.spec_pairs``) rather than hardcoded name strings, so adding a
policy or re-pointing a table at another registered environment is a
config edit, not a code change.

Prints a ``name,us_per_call,derived`` CSV summary line per benchmark
(us_per_call = wall time per simulated routing round or kernel call;
derived = the headline metric of that table), plus each module's own
detailed table. Full payloads land in results/benchmarks/*.json.

Run: ``PYTHONPATH=src python -m benchmarks.run``
"""
from __future__ import annotations

import sys
import time

from benchmarks import (appendix_context, bench_driver, bench_fused,
                        bench_kernels, bench_neural, bench_serving_faults,
                        bench_user_store, fig2_budget_cdf,
                        fig3_budget_sensitivity, table1_2_accuracy_cost,
                        table3_position, theorem_regret)
from benchmarks import common


def main() -> None:
    rows = []
    all_claims = {}

    suites = [
        ("table1_2_accuracy_cost", table1_2_accuracy_cost,
         lambda p: p["accuracy"]["knapsack"]["avg"]),
        ("table3_position", table3_position,
         lambda p: p["knapsack"]["first_step_share"]),
        ("fig2_budget_cdf", fig2_budget_cdf,
         lambda p: p["budget_linucb"]["within_budget_frac"]),
        ("fig3_budget_sensitivity", fig3_budget_sensitivity,
         lambda p: list(p["knapsack"].values())[-1]),
        ("theorem_regret", theorem_regret,
         lambda p: p["greedy_linucb"]["loglog_slope"]),
        ("appendix_context", appendix_context,
         lambda p: p["strategy2_mistral_then_gemini"]
         - p["strategy1_gemini_only"]),
        ("bench_kernels", bench_kernels,
         lambda p: p["linucb_score_B128_K6_d384"]),
        ("bench_driver", bench_driver,
         lambda p: p["pool_d64_sweep6_greedy_linucb"]["speedup"]),
        ("bench_fused", bench_fused,
         lambda p: p["round_d64"]["speedup"]),
        ("bench_neural", bench_neural,
         lambda p: p["pipeline"]["neural"]["accuracy_mean"]
         - p["pipeline"]["linear"]["accuracy_mean"]),
        ("bench_serving_faults", bench_serving_faults,
         lambda p: p["regret_ratio"]),
        ("bench_user_store", bench_user_store,
         lambda p: p["cold_start_regret_ratio"]),
    ]

    for name, mod, derive in suites:
        t0 = time.perf_counter()
        payload, claims = mod.main()
        # every suite's full payload lands under its SUITE name — the
        # modules' own save_json calls use assorted short names
        # (table1_2, table3, …), so the harness writes the canonical
        # per-suite files results/benchmarks/<suite>.json itself
        common.save_json(name, payload)
        dt = time.perf_counter() - t0
        # per-round (or per-call) time in µs
        rounds = common.ROUNDS if not name.startswith("bench") else 1
        us = dt / max(rounds, 1) * 1e6
        rows.append((name, us, derive(payload)))
        all_claims[name] = claims

    print("\n================ SUMMARY (name,us_per_call,derived) ===========")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")

    failed = {k: {c: ok for c, ok in v.items() if not ok}
              for k, v in all_claims.items() if not all(v.values())}
    print("\nclaim checks:",
          "ALL PASS" if not failed else f"FAILURES: {failed}")
    common.save_json("claims", all_claims)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
