"""Benchmark harness entry point: one module per paper table/figure.

Table rows are spec-driven: each table module iterates a list of
``(EnvSpec, PolicySpec)`` pairs (``common.TABLE_CONFIGS`` /
``common.spec_pairs``) rather than hardcoded name strings, so adding a
policy or re-pointing a table at another registered environment is a
config edit, not a code change.

Prints a ``name,us_per_call,derived`` CSV summary line per benchmark
(us_per_call = wall time per simulated routing round or kernel call;
derived = the headline metric of that table), plus each module's own
detailed table. Full payloads land in results/benchmarks/*.json, and
every suite also emits an observability snapshot
(``<suite>.metrics.json`` — wall time, headline, claim pass/fail as a
:class:`repro.obs.MetricsRegistry` export) next to its payload.

Run: ``PYTHONPATH=src python -m benchmarks.run`` (all suites), or name
a subset: ``python -m benchmarks.run bench_obs bench_fused``. With
``--all`` the harness additionally writes
``results/benchmarks/summary.json`` — one machine-readable entry per
suite (headline claim, key numbers, pass/fail) so the perf trajectory
across PRs lives in one file.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (appendix_context, bench_driver, bench_fused,
                        bench_kernels, bench_neural, bench_obs,
                        bench_serving_faults, bench_user_store,
                        fig2_budget_cdf, fig3_budget_sensitivity,
                        table1_2_accuracy_cost, table3_position,
                        theorem_regret)
from benchmarks import common
from repro import obs as obs_mod


SUITES = [
    ("table1_2_accuracy_cost", table1_2_accuracy_cost,
     lambda p: p["accuracy"]["knapsack"]["avg"]),
    ("table3_position", table3_position,
     lambda p: p["knapsack"]["first_step_share"]),
    ("fig2_budget_cdf", fig2_budget_cdf,
     lambda p: p["budget_linucb"]["within_budget_frac"]),
    ("fig3_budget_sensitivity", fig3_budget_sensitivity,
     lambda p: list(p["knapsack"].values())[-1]),
    ("theorem_regret", theorem_regret,
     lambda p: p["greedy_linucb"]["loglog_slope"]),
    ("appendix_context", appendix_context,
     lambda p: p["strategy2_mistral_then_gemini"]
     - p["strategy1_gemini_only"]),
    ("bench_kernels", bench_kernels,
     lambda p: p["linucb_score_B128_K6_d384"]),
    ("bench_driver", bench_driver,
     lambda p: p["pool_d64_sweep6_greedy_linucb"]["speedup"]),
    ("bench_fused", bench_fused,
     lambda p: p["round_d64"]["speedup"]),
    ("bench_neural", bench_neural,
     lambda p: p["pipeline"]["neural"]["accuracy_mean"]
     - p["pipeline"]["linear"]["accuracy_mean"]),
    ("bench_serving_faults", bench_serving_faults,
     lambda p: p["regret_ratio"]),
    ("bench_user_store", bench_user_store,
     lambda p: p["cold_start_regret_ratio"]),
    ("bench_obs", bench_obs,
     lambda p: p["driver_d64"]["overhead"]),
]


def _suite_metrics(name: str, wall_s: float, us: float, derived: float,
                   claims: dict) -> None:
    """The per-suite observability snapshot: a tiny registry of
    suite-level gauges exported next to the payload JSON."""
    obs = obs_mod.Obs()
    reg = obs.registry
    reg.set("suite_wall_s", wall_s, labels={"suite": name})
    reg.set("suite_us_per_call", us, labels={"suite": name})
    reg.set("suite_derived", float(derived), labels={"suite": name})
    reg.set("suite_claims_total", float(len(claims)),
            labels={"suite": name})
    reg.set("suite_claims_passed", float(sum(map(bool, claims.values()))),
            labels={"suite": name})
    common.save_json(f"{name}.metrics", obs.snapshot())


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("suites", nargs="*",
                    help="suite names to run (default: all)")
    ap.add_argument("--all", action="store_true",
                    help="run every suite AND write "
                         "results/benchmarks/summary.json")
    ap.add_argument("--summary", action="store_true",
                    help="write summary.json for whatever suites ran "
                         "(implied by --all; lets CI consolidate a "
                         "quick subset)")
    args = ap.parse_args(argv)

    selected = SUITES
    if args.suites and not args.all:
        known = {name for name, _, _ in SUITES}
        unknown = [s for s in args.suites if s not in known]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; choose from "
                     f"{sorted(known)}")
        selected = [row for row in SUITES if row[0] in args.suites]

    rows = []
    all_claims = {}
    summary = {}

    for name, mod, derive in selected:
        t0 = time.perf_counter()
        payload, claims = mod.main()
        # every suite's full payload lands under its SUITE name — the
        # modules' own save_json calls use assorted short names
        # (table1_2, table3, …), so the harness writes the canonical
        # per-suite files results/benchmarks/<suite>.json itself
        common.save_json(name, payload)
        dt = time.perf_counter() - t0
        # per-round (or per-call) time in µs
        rounds = common.ROUNDS if not name.startswith("bench") else 1
        us = dt / max(rounds, 1) * 1e6
        derived = derive(payload)
        rows.append((name, us, derived))
        all_claims[name] = claims
        _suite_metrics(name, dt, us, derived, claims)
        summary[name] = {
            "headline": float(derived),
            "us_per_call": us,
            "wall_s": dt,
            "claims": claims,
            "pass": all(claims.values()),
        }

    print("\n================ SUMMARY (name,us_per_call,derived) ===========")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.4f}")

    failed = {k: {c: ok for c, ok in v.items() if not ok}
              for k, v in all_claims.items() if not all(v.values())}
    print("\nclaim checks:",
          "ALL PASS" if not failed else f"FAILURES: {failed}")
    common.save_json("claims", all_claims)
    if args.all or args.summary:
        common.save_json("summary", summary)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
