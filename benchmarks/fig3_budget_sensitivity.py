"""Paper Figure 3: budget-sensitivity sweep on the AIME stream.

Claims validated (§6.1.4): near-zero budgets yield near-zero accuracy;
accuracy grows with budget; the knapsack heuristic scales better at large
budgets (it overtakes budget-aware LinUCB as budget grows).
"""
from __future__ import annotations

from typing import Dict

from benchmarks import common

AIME = 1   # dataset index
BUDGETS = (5e-5, 1.5e-4, 5e-4, 1e-3, 2e-3, 5e-3, 2e-2)


def run() -> Dict:
    out: Dict[str, Dict[str, float]] = {"budget_linucb": {},
                                        "knapsack": {}}
    for policy in out:
        for b in BUDGETS:
            res, _ = common.run_policy(
                policy, rounds=max(common.ROUNDS // 2, 200),
                dataset=AIME, base_budget=b)
            out[policy][f"{b:.0e}"] = res.accuracy
    common.save_json("fig3_budget_sensitivity", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    bl = list(out["budget_linucb"].values())
    ks = list(out["knapsack"].values())
    return {
        "tiny_budget_near_zero": bl[0] < 0.15 and ks[0] < 0.15,
        "accuracy_grows_with_budget": bl[-1] > bl[0] and ks[-1] > ks[0],
        "knapsack_scales_at_large_budget": ks[-1] >= bl[-1],
    }


def main():
    out = run()
    print("\n=== Fig 3 (budget sensitivity, AIME stream) ===")
    print("budget," + ",".join(out.keys()))
    for i, b in enumerate(BUDGETS):
        key = f"{b:.0e}"
        print(f"{key}," + ",".join(f"{100*out[p][key]:.1f}"
                                   for p in out))
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
