"""Observability overhead benchmark: the obs= hooks must be ~free.

The ``repro.obs`` contract has two halves, and this suite measures both:

* **Off is bitwise-invisible.** ``obs=None`` must trace the exact
  pre-obs program — asserted here by running the d=64 scan driver and a
  chaos serving loop with and without ``obs=`` and comparing results
  field-by-field (driver logs bitwise, serving report exactly, modulo
  wall-clock fields).
* **On is ≤ 5% overhead.** Device metrics ride the scan carry as ONE
  packed vector updated by one fused scatter-add per round, flushed
  once per chunk; serving counters/spans are O(1) host appends per
  event. Overhead is the median of interleaved per-pair off/on ratios
  (adjacent samples share container load, so the ratio is robust to
  the box's noisy-neighbor swings) and the claim run FAILS if either
  path regresses past 5%.

A structural audit backs the timing: the obs-on chunk program must
contain the same number of ``pallas_call``s as the obs-off one (metrics
add arithmetic, never kernel launches) and must not materialize a
per-arm (K, d, d) tensor. The obs-on chaos run also exports its
Perfetto trace to ``results/traces/serve_chaos.json`` (git-ignored; CI
uploads it as an artifact).

Run: ``PYTHONPATH=src python -m benchmarks.bench_obs``
"""
from __future__ import annotations

import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import obs as obs_mod
from repro.core import env as env_mod
from repro.core import linucb
from repro.core import policy as policy_mod
from repro.engine import driver
from repro.obs import metrics as obs_metrics
from repro.serving.faults import FaultSpec, SyntheticArmPool, bursty_arrivals
from repro.serving.runtime import (HealthConfig, RetryPolicy, RuntimeConfig,
                                   ServingRuntime)
from repro.serving.scheduler import ArmSpec, BanditScheduler

ROUNDS = int(os.environ.get("REPRO_BENCH_OBS_ROUNDS", "4000"))
REPS = int(os.environ.get("REPRO_BENCH_OBS_REPS", "15"))
MAX_OVERHEAD = 1.05
RESULT_FIELDS = ("arms", "rewards", "costs", "regrets", "budgets",
                 "datasets")
TRACE_DIR = os.path.join(os.path.dirname(common.RESULTS_DIR.rstrip("/"))
                         or ".", "traces")


def _paired_overhead(fn_off, fn_on, reps: int = REPS):
    """Measure obs overhead as the MEDIAN of per-pair ratios over
    interleaved (off, on) samples.

    A ≤5% claim cannot survive this container's ±40% noisy-neighbor
    swings with block medians or best-of-N minima (both compare samples
    taken under different load). Adjacent off/on samples share nearly
    the same load, so each pair's ratio centers on the true overhead
    and the median sheds the pairs a load step landed inside. Returns
    ``(off_best_s, on_best_s, overhead)`` — the minima are reported for
    throughput only; the claim is the median pair ratio."""
    offs, ons = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn_off()
        offs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_on()
        ons.append(time.perf_counter() - t0)
    ratios = sorted(on / off for off, on in zip(offs, ons))
    return min(offs), min(ons), ratios[len(ratios) // 2]


# ---------------------------------------------------------------------------
# d=64 scan driver: obs-off vs obs-on
# ---------------------------------------------------------------------------

def _driver_compare() -> Dict[str, object]:
    env64 = env_mod.CalibratedPoolEnv(dim=64)

    def run(obs=None):
        return driver.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                          env=env64, obs=obs)

    res_off = run()                                  # warm the off program
    obs_on = obs_mod.Obs()
    res_on = run(obs_on)                             # warm the on program
    parity = all(np.array_equal(getattr(res_off, f), getattr(res_on, f))
                 for f in RESULT_FIELDS)

    off_s, on_s, overhead = _paired_overhead(
        run, lambda: run(obs_mod.Obs()))

    # the device metrics must agree with the logs they rode along with
    reg = obs_on.registry
    pulls = reg.value("pulls")
    executed = res_on.arms[res_on.arms >= 0]
    metrics_ok = (
        int(reg.value("rounds")) == ROUNDS
        and int(pulls.sum()) == executed.size
        and np.array_equal(pulls, np.bincount(executed,
                                              minlength=pulls.size))
        and abs(reg.value("regret_sum") - float(res_on.regrets.sum()))
        <= 1e-3 * max(1.0, abs(float(res_on.regrets.sum()))))

    return {
        "rounds": ROUNDS,
        "off_s": off_s,
        "on_s": on_s,
        "off_rounds_per_s": ROUNDS / off_s,
        "on_rounds_per_s": ROUNDS / on_s,
        "overhead": overhead,
        "bitwise_parity": bool(parity),
        "metrics_consistent": bool(metrics_ok),
    }


# ---------------------------------------------------------------------------
# chaos serving loop: obs-off vs obs-on (+ trace export)
# ---------------------------------------------------------------------------

def _chaos_runtime(obs=None, trace_len_s: float = 20.0):
    pool = SyntheticArmPool(4, 16, seed=1)
    arms = [ArmSpec(f"a{k}", None, float(pool.costs[k]))
            for k in range(4)]
    sched = BanditScheduler(arms, dim=16, alpha=1.0, obs=obs)
    cfg = RuntimeConfig(
        max_batch=16, ring_capacity=8, timeout_s=0.25, deadline_s=8.0,
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.05,
                          max_delay_s=0.5),
        health=HealthConfig(window=12, fail_threshold=0.6, min_samples=4,
                            probe_interval_s=0.5))
    rt = ServingRuntime(
        sched, pool.arm_fns(),
        faults=FaultSpec(timeout_rate=0.15, error_rate=0.1,
                         drop_feedback_rate=0.2, seed=7),
        config=cfg, oracle=pool.oracle, obs=obs)
    times = bursty_arrivals(t_end=trace_len_s, rate=10.0, seed=11)
    rt.submit_trace(pool.contexts(len(times), seed=5), times)
    return rt


_WALL_KEYS = ("wall_s", "user_rounds_per_s", "route_p50_ms", "route_p99_ms")


def _serving_compare() -> Dict[str, object]:
    rep_off = _chaos_runtime().run()                 # warm programs
    obs_on = obs_mod.Obs(trace=True)
    rep_on = _chaos_runtime(obs_on).run()
    s_off, s_on = rep_off.summary(), rep_on.summary()
    parity = all(s_off[k] == s_on[k] for k in s_off if k not in _WALL_KEYS)

    os.makedirs(TRACE_DIR, exist_ok=True)
    trace_path = os.path.join(TRACE_DIR, "serve_chaos.json")
    obs_on.export_trace(trace_path)

    off_s, on_s, overhead = _paired_overhead(
        lambda: _chaos_runtime().run(),
        lambda: _chaos_runtime(obs_mod.Obs(trace=True)).run())

    reg = obs_on.registry
    counters_ok = (
        int(reg.value("rt_admitted")) == rep_on.admitted
        and int(reg.value("rt_feedback_arrived")) == rep_on.feedback_arrived
        and int(reg.value("ring_folded_rows")) == rep_on.feedback_folded
        and reg.value("rt_lost_feedback") == 0.0)

    return {
        "served": len(rep_on.served),
        "off_s": off_s,
        "on_s": on_s,
        "off_requests_per_s": len(rep_off.served) / off_s,
        "on_requests_per_s": len(rep_on.served) / on_s,
        "overhead": overhead,
        "report_parity": bool(parity),
        "counters_consistent": bool(counters_ok),
        "trace_events": len(obs_on.trace.events),
        "trace_path": trace_path,
    }


# ---------------------------------------------------------------------------
# structural audit: metrics add arithmetic, never launches
# ---------------------------------------------------------------------------

def _audit_round_body() -> Dict[str, object]:
    env64 = env_mod.CalibratedPoolEnv(dim=64)
    spec = policy_mod.as_spec("greedy_linucb")
    chunk = 32
    backend = "pallas" if jax.default_backend() == "tpu" \
        else "pallas_interpret"
    with linucb.backend_scope(backend):
        be = linucb.resolved_backend()
        key = jax.random.PRNGKey(0)
        kenv, kround = jax.random.split(key)
        params = env64.make(kenv)
        table = driver._pool_budget_table(1e-3, env64.num_datasets, False)
        ts = jnp.arange(chunk, dtype=jnp.int32)
        schema = obs_metrics.round_schema(env64.num_arms,
                                          env64.num_datasets)

        pol, _, chunk_off = driver._jitted_pool_drivers(
            spec, env64, 0.675, 0.45, ROUNDS, env64.max_cost(), 0, 0.05,
            None, be, False)
        _, _, chunk_on = driver._jitted_pool_drivers(
            spec, env64, 0.675, 0.45, ROUNDS, env64.max_cost(), 0, 0.05,
            None, be, False, schema, ROUNDS)

        audit_off = obs_mod.jaxpr_audit(
            chunk_off.__wrapped__, params, pol.init(), kround, table, ts)
        audit_on = obs_mod.jaxpr_audit(
            chunk_on.__wrapped__, params, (pol.init(), schema.init()),
            kround, table, ts)
        # the claim-run guard: obs adds no launches, no (K, d, d)
        audit_on.expect(
            pallas_calls=audit_off.pallas_calls,
            banned=[obs_mod.shape_sig(env64.num_arms, 64, 64)])
    return {
        "backend": backend,
        "pallas_calls_off": audit_off.pallas_calls,
        "pallas_calls_on": audit_on.pallas_calls,
        "launch_parity": audit_off.pallas_calls == audit_on.pallas_calls,
    }


def run() -> Dict:
    out: Dict[str, object] = {"max_overhead": MAX_OVERHEAD}
    with obs_mod.profile_session("bench_obs"):
        out["driver_d64"] = _driver_compare()
        out["serving_chaos"] = _serving_compare()
    out["audit"] = _audit_round_body()
    common.save_json("bench_obs", out)
    return out


def main():
    out = run()
    d, s = out["driver_d64"], out["serving_chaos"]
    print("\n=== Observability overhead (obs-off vs obs-on) ===")
    print(f"driver_d64: {d['off_rounds_per_s']:.0f} rounds/s off vs "
          f"{d['on_rounds_per_s']:.0f} on "
          f"(overhead {d['overhead']:.3f}x, parity={d['bitwise_parity']})")
    print(f"serving_chaos: {s['off_requests_per_s']:.0f} req/s off vs "
          f"{s['on_requests_per_s']:.0f} on "
          f"(overhead {s['overhead']:.3f}x, parity={s['report_parity']}, "
          f"{s['trace_events']} trace events)")
    print(f"audit: {out['audit']['pallas_calls_off']} pallas launches "
          f"off == {out['audit']['pallas_calls_on']} on")
    claims = {
        "driver_overhead_le_5pct": d["overhead"] <= MAX_OVERHEAD,
        "serving_overhead_le_5pct": s["overhead"] <= MAX_OVERHEAD,
        "driver_bitwise_parity": d["bitwise_parity"],
        "driver_metrics_consistent": d["metrics_consistent"],
        "serving_report_parity": s["report_parity"],
        "serving_counters_consistent": s["counters_consistent"],
        "obs_adds_no_launches": out["audit"]["launch_parity"],
    }
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    import sys
    _, claims = main()
    if not all(claims.values()):
        sys.exit(1)
