"""Paper Table 3: accuracy decomposed by the step at which the round was
solved + average steps, for the three proposed configurations.

Claim validated (§6.1.2): the positionally-aware knapsack concentrates its
accuracy at step 1 (≥80% of its total in our sim) and uses the fewest
average steps of the three.
"""
from __future__ import annotations

from typing import Dict

from benchmarks import common


def run() -> Dict:
    import numpy as np
    out: Dict[str, Dict] = {}
    for name in common.OUR_POLICIES:
        per_ds, dt = common.run_policy_per_dataset(name)
        by_pos = np.mean([res.accuracy_by_position()
                          for res in per_ds.values()], axis=0)
        acc = float(np.mean([res.accuracy for res in per_ds.values()]))
        steps = float(np.mean([res.avg_steps for res in per_ds.values()]))
        gamma = 0.8   # positional discount: earlier successes worth more
        util = float(sum(gamma ** i * v for i, v in enumerate(by_pos)))
        out[name] = {
            "total_accuracy": acc,
            "avg_steps": steps,
            "by_position": {f"step{i+1}": float(v)
                            for i, v in enumerate(by_pos)},
            "first_step_share": float(by_pos[0] / max(acc, 1e-9)),
            "positional_utility_g0.8": util,
            "time_s": dt,
        }
    common.save_json("table3", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    """REPRODUCTION NOTE: the paper's 95% step-1 share for the knapsack
    does NOT reproduce under costs calibrated to its own Table 2 — there,
    cost and quality are only weakly correlated (the weak Mistral is the
    most expensive arm on GPQA/AIME), so the budget rarely forces
    single-pull rounds. What does reproduce: fewest average steps and the
    best positionally-discounted utility for the knapsack heuristic."""
    ks = out["knapsack"]
    return {
        "knapsack_fewest_steps": ks["avg_steps"] == min(
            v["avg_steps"] for v in out.values()),
        # vs the other BUDGETED policy (greedy is unbudgeted, so its raw
        # utility isn't cost-comparable) + within 0.02 of unbudgeted greedy
        "knapsack_best_budgeted_positional_utility":
            ks["positional_utility_g0.8"]
            > out["budget_linucb"]["positional_utility_g0.8"]
            and ks["positional_utility_g0.8"]
            >= out["greedy_linucb"]["positional_utility_g0.8"] - 0.02,
        "all_policies_frontload_majority":
            all(v["first_step_share"] > 0.45 for v in out.values()),
    }


def main():
    out = run()
    print("\n=== Table 3 (position decomposition) ===")
    print("policy,total_acc,avg_steps,step1,step2,step3,step4,"
          "step1_share,pos_util")
    for k, v in out.items():
        bp = v["by_position"]
        print(f"{k},{100*v['total_accuracy']:.2f},{v['avg_steps']:.3f},"
              + ",".join(f"{100*bp[f'step{i}']:.2f}" for i in range(1, 5))
              + f",{100*v['first_step_share']:.1f}%"
              + f",{v['positional_utility_g0.8']:.3f}")
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
