"""Paper Table 3: accuracy decomposed by the step at which the round was
solved + average steps, for the three proposed configurations PLUS the
registered positionally-aware extension (``positional_linucb`` —
``PositionalWeight`` over the greedy LinUCB base, ``core.policy``).

Claim validated (§6.1.2): the positionally-aware knapsack concentrates its
accuracy at step 1 (≥80% of its total in our sim) and uses the fewest
average steps of the three. Extension claim: ``positional_linucb``'s
position-discounted exploration lifts first-step accuracy to at least the
undiscounted greedy baseline's.

Aggregation is streaming: every run folds its chunk logs through the
engine's :class:`~repro.engine.aggregate.StreamingSummary` reducer
(``run_policy_per_dataset(streamed=True)``) — no ``(T, H)`` result arrays
are materialized.
"""
from __future__ import annotations

from typing import Dict

from benchmarks import common

POLICIES = common.OUR_POLICIES + ("positional_linucb",)
# spec-driven row list: (EnvSpec, PolicySpec) pairs on the pool env
CONFIGS = common.spec_pairs(*POLICIES)


def run() -> Dict:
    import numpy as np
    out: Dict[str, Dict] = {}
    for env_spec, spec in CONFIGS:
        name = common.policy_label(spec)
        per_ds, dt = common.run_policy_per_dataset(spec, streamed=True,
                                                   env=env_spec)
        by_pos = np.mean([res.accuracy_by_position()
                          for res in per_ds.values()], axis=0)
        acc = float(np.mean([res.accuracy for res in per_ds.values()]))
        steps = float(np.mean([res.avg_steps for res in per_ds.values()]))
        gamma = 0.8   # positional discount: earlier successes worth more
        util = float(sum(gamma ** i * v for i, v in enumerate(by_pos)))
        out[name] = {
            "total_accuracy": acc,
            "avg_steps": steps,
            "by_position": {f"step{i+1}": float(v)
                            for i, v in enumerate(by_pos)},
            "first_step_share": float(by_pos[0] / max(acc, 1e-9)),
            "positional_utility_g0.8": util,
            "time_s": dt,
        }
    common.save_json("table3", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    """REPRODUCTION NOTE: the paper's 95% step-1 share for the knapsack
    does NOT reproduce under costs calibrated to its own Table 2 — there,
    cost and quality are only weakly correlated (the weak Mistral is the
    most expensive arm on GPQA/AIME), so the budget rarely forces
    single-pull rounds. What does reproduce: fewest average steps and the
    best positionally-discounted utility for the knapsack heuristic
    (among the paper's three). The registered ``positional_linucb``
    extension must lift first-step accuracy at least to greedy's."""
    ks = out["knapsack"]
    pos = out["positional_linucb"]
    greedy = out["greedy_linucb"]
    return {
        # the paper's three, as before (the extension competes separately)
        "knapsack_fewest_steps": ks["avg_steps"] == min(
            out[p]["avg_steps"] for p in common.OUR_POLICIES),
        # vs the other BUDGETED policy (greedy is unbudgeted, so its raw
        # utility isn't cost-comparable) + within 0.02 of unbudgeted greedy
        "knapsack_best_budgeted_positional_utility":
            ks["positional_utility_g0.8"]
            > out["budget_linucb"]["positional_utility_g0.8"]
            and ks["positional_utility_g0.8"]
            >= greedy["positional_utility_g0.8"] - 0.02,
        "all_policies_frontload_majority":
            all(v["first_step_share"] > 0.45 for v in out.values()),
        # at the paper's small α=0.675 the positional discount's edge is
        # within single-seed noise (the α-sensitive statistical test
        # lives in tests/test_policy_api.py); require competitiveness
        "positional_first_step_competitive":
            pos["by_position"]["step1"]
            >= greedy["by_position"]["step1"] - 0.02,
        "positional_steps_competitive":
            pos["avg_steps"] <= greedy["avg_steps"] + 0.05,
    }


def main():
    out = run()
    print("\n=== Table 3 (position decomposition) ===")
    print("policy,total_acc,avg_steps,step1,step2,step3,step4,"
          "step1_share,pos_util")
    for k, v in out.items():
        bp = v["by_position"]
        print(f"{k},{100*v['total_accuracy']:.2f},{v['avg_steps']:.3f},"
              + ",".join(f"{100*bp[f'step{i}']:.2f}" for i in range(1, 5))
              + f",{100*v['first_step_share']:.1f}%"
              + f",{v['positional_utility_g0.8']:.3f}")
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
