"""Per-user posterior store benchmark: population scaling + the cohort
prior's cold-start payoff.

Two tables into ``bench_user_store.json``:

* **Population scaling** — sustained user-rounds/s of the multi-stream
  engine at d=64 as the user population grows, U ∈ {1, 64, 1024}
  (``run_pool_multistream(users=U)``): U=1 is the shared-posterior
  baseline; U>1 swaps the batched fold for the user-gridded pool fold
  (``linucb.pool_batch_update`` — the scalar-prefetched selected-block
  Sherman–Morrison kernel on the pallas backend) and gathers each
  stream's user posterior per round. The table records how much the
  per-user axis costs relative to the shared posterior at matched
  traffic.
* **Cold-start regret, cohort vs flat prior** — a
  :class:`repro.serving.state_store.UserStateStore` serves a warmup
  population, then a wave of NEVER-SEEN users arrives; their regret over
  their first requests is measured under the hierarchical cohort
  warm-start against an identical run whose new users get the flat
  ``λ⁻¹I`` prior. Same seeds, same traffic, same arms — the only
  difference is the admission prior, so the gap is the hierarchical
  layer's payoff.

Claims checked by ``benchmarks.run``: every multistream config sustains
positive throughput, routing under U=1024 stays within a sanity factor
of U=1, and the cohort prior's cold-start regret does not exceed the
flat prior's (the hierarchical prior can only help a homogeneous-taste
population).

Run: ``PYTHONPATH=src python -m benchmarks.bench_user_store``
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import numpy as np

from benchmarks import common
from repro.core import env as env_mod
from repro.core import linucb, router
from repro.serving.faults import SyntheticArmPool
from repro.serving.state_store import UserStateStore

DIM = 64
USER_GRID = (1, 64, 1024)
STREAMS = int(os.environ.get("REPRO_BENCH_STORE_STREAMS", "32"))
MS_ROUNDS = int(os.environ.get("REPRO_BENCH_STORE_ROUNDS", "64"))

NUM_ARMS = 6
WARM_USERS, WARM_REQS = 24, 480
COLD_USERS, COLD_REQS_EACH = 16, 4
CAPACITY = 16
SLOWDOWN_BOUND = 25.0   # U=1024 routing ≤ this × slower than U=1


def _multistream_throughput() -> Dict[str, Dict[str, float]]:
    env64 = env_mod.CalibratedPoolEnv(dim=DIM)
    out = {}
    for users in USER_GRID:
        run = lambda: router.run_pool_multistream(
            "greedy_linucb", rounds=MS_ROUNDS, streams=STREAMS,
            users=users, env=env64, chunk_size=16)
        run()                                      # warm the jit cache
        secs = common.median_secs(run)
        out[f"U{users}"] = {
            "users": users,
            "streams": STREAMS,
            "rounds": MS_ROUNDS,
            "wall_s": secs,
            "user_rounds_per_s": MS_ROUNDS * STREAMS / secs,
        }
    return out


def _cold_start_regret() -> Dict[str, Dict[str, float]]:
    """Identical warmup + cold-user traffic under both admission priors."""
    pool = SyntheticArmPool(NUM_ARMS, DIM, seed=3)
    rng = np.random.default_rng(17)
    warm_uids = rng.integers(0, WARM_USERS, WARM_REQS)
    warm_ctx = pool.contexts(WARM_REQS, seed=23)
    cold_ctx = pool.contexts(COLD_USERS * COLD_REQS_EACH, seed=29)
    cold_uids = np.repeat(np.arange(WARM_USERS,
                                    WARM_USERS + COLD_USERS),
                          COLD_REQS_EACH)
    arm_fns = pool.arm_fns()

    out = {}
    for label, cohort in (("cohort_prior", True), ("flat_prior", False)):
        cfg = linucb.LinUCBConfig(num_arms=NUM_ARMS, dim=DIM, alpha=1.0)
        store = UserStateStore(cfg, CAPACITY, cohort_prior=cohort)
        # warmup population: the cohort posterior learns the pool's
        # global preference structure from every member's feedback
        for lo in range(0, WARM_REQS, CAPACITY):
            uids = warm_uids[lo:lo + CAPACITY]
            xs = warm_ctx[lo:lo + CAPACITY]
            arms = store.route(uids, xs)
            rewards = [arm_fns[a](x, np.random.default_rng(
                (lo + i) * 7 + a))[0] for i, (a, x) in
                enumerate(zip(arms, xs))]
            store.fold(uids, arms, xs, np.asarray(rewards, np.float32))
        # cold wave: never-seen users; charge oracle regret per request
        regret, t0 = 0.0, time.perf_counter()
        for i in range(len(cold_uids)):
            uid, x = int(cold_uids[i]), cold_ctx[i]
            arm = int(store.route([uid], x[None])[0])
            probs = pool.oracle(x)
            regret += float(np.max(probs) - probs[arm])
            reward = arm_fns[arm](x, np.random.default_rng(i * 13 + arm))[0]
            store.fold([uid], [arm], x[None],
                       np.asarray([reward], np.float32))
        out[label] = {
            "cold_users": COLD_USERS,
            "requests_per_user": COLD_REQS_EACH,
            "cold_start_regret": regret,
            "regret_per_request": regret / len(cold_uids),
            "wall_s": time.perf_counter() - t0,
            "evictions": store.evictions,
            "restores": store.restores,
        }
    return out


def run() -> Tuple[Dict, Dict]:
    throughput = _multistream_throughput()
    cold = _cold_start_regret()
    payload = {"dim": DIM, "throughput": throughput, "cold_start": cold,
               "slowdown_bound": SLOWDOWN_BOUND}

    r1 = throughput["U1"]["user_rounds_per_s"]
    r1024 = throughput["U1024"]["user_rounds_per_s"]
    cohort = cold["cohort_prior"]["cold_start_regret"]
    flat = cold["flat_prior"]["cold_start_regret"]
    payload["cold_start_regret_ratio"] = cohort / max(flat, 1e-9)
    claims = {
        "all_configs_positive_throughput": all(
            v["user_rounds_per_s"] > 0 for v in throughput.values()),
        "u1024_within_slowdown_bound": r1024 * SLOWDOWN_BOUND >= r1,
        "cohort_prior_no_worse_than_flat": cohort <= flat,
    }
    return payload, claims


def main():
    payload, claims = run()
    common.save_json("bench_user_store", payload)
    print("\n=== Per-user posterior store (d=64) ===")
    for k, v in payload["throughput"].items():
        print(f"multistream {k:6s} {v['user_rounds_per_s']:10.0f} "
              f"user-rounds/s  ({v['wall_s']:.3f}s for "
              f"{v['rounds']}x{v['streams']} rounds)")
    for k, v in payload["cold_start"].items():
        print(f"cold-start {k:13s} regret {v['cold_start_regret']:.3f} "
              f"({v['regret_per_request']:.4f}/req, "
              f"evictions {v['evictions']}, restores {v['restores']})")
    print(f"cohort/flat cold-start regret ratio = "
          f"{payload['cold_start_regret_ratio']:.3f}")
    print("claims:", claims)
    return payload, claims


if __name__ == "__main__":
    _, _claims = main()
    if not all(_claims.values()):
        raise SystemExit(1)
