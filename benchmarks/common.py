"""Shared benchmark plumbing: run (environment, policy) spec pairs,
cache results as JSON, time everything.

The tables iterate over explicit ``(EnvSpec, PolicySpec)`` pairs
(:func:`spec_pairs` / :data:`TABLE_CONFIGS`) instead of hardcoded name
strings — adding a policy or pointing a table at another registered
environment is a one-line config change. Name strings still work
everywhere (they normalize through the same specs).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import env as env_mod
from repro.core import policy as policy_mod
from repro.core import router
from repro.core.scenario import EnvSpec

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")
ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "800"))
# replications per table/figure entry; seeds 0..SEEDS-1 run as ONE
# vmapped program (router.run_pool_experiment_sweep)
SEEDS = int(os.environ.get("REPRO_BENCH_SEEDS", "3"))

OUR_POLICIES = ("greedy_linucb", "budget_linucb", "knapsack")
BASELINES = ("metallm", "mixllm", "voting", "random")
FIXED = tuple(f"fixed:{k}" for k in range(len(env_mod.ARM_NAMES)))

POOL_SPEC = EnvSpec.from_name("calibrated_pool")
PIPELINE_SPEC = EnvSpec.from_name("pipeline")


def spec_pairs(*policies, env: EnvSpec = POOL_SPEC):
    """Normalize policy names/specs into ``(EnvSpec, PolicySpec)`` pairs."""
    return tuple((env, policy_mod.as_spec(p)) for p in policies)


# What Table 1/2 iterates: every candidate LLM, every baseline router,
# and the paper's three policies, all on the Tables-1/2-calibrated pool.
TABLE_CONFIGS = spec_pairs(*(FIXED + BASELINES + OUR_POLICIES))


def policy_label(policy) -> str:
    """Human-readable row label (``fixed:k`` → the arm's LLM name)."""
    spec = policy_mod.as_spec(policy)
    if spec.name == "fixed":
        return env_mod.ARM_NAMES[int(spec.kwargs["arm"])]
    return spec.label


def dataset_streams(env: EnvSpec = POOL_SPEC):
    """``(index, label)`` pairs for the env's dataset streams — the pool
    env's paper benchmark names, generic ``stream<i>`` labels otherwise
    (the per-dataset helpers iterate THIS, not the pool's DATASETS, so
    pointing a table at a one-stream env runs one stream, not four
    mislabeled copies)."""
    if env.name == "calibrated_pool":
        return list(enumerate(env_mod.DATASETS))
    n = env.make_env().num_datasets
    return [(i, f"stream{i}") for i in range(n)]


def ensure_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def median_secs(fn, reps: int = 3) -> float:
    """Median wall-clock of ``reps`` runs — the container's vCPUs are
    noisy neighbors and a single sample swings ±40%. Callers warm the
    jit caches first; shared by bench_driver and bench_kernels so their
    timing protocols cannot drift apart."""
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2]


_GREEDY_CACHE: Dict[tuple, object] = {}


def greedy_reference(dataset: int, seed: int = 0, env: EnvSpec = POOL_SPEC):
    """Cached greedy-LinUCB run per (env, dataset, seed) — both a Table-1
    row and the budget reference (paper: per-query budget = greedy's avg
    cost ±5%). Keyed on the seed too, so non-zero-seed budgeted runs
    never inherit another seed's budget."""
    key = (env, dataset, seed)
    if key not in _GREEDY_CACHE:
        _GREEDY_CACHE[key] = router.run_pool_experiment(
            "greedy_linucb", rounds=ROUNDS, seed=seed, dataset=dataset,
            env=env)
    return _GREEDY_CACHE[key]


def dataset_budget(dataset: int, seed: int = 0,
                   env: EnvSpec = POOL_SPEC) -> float:
    return float(greedy_reference(dataset, seed, env)
                 .cost_per_round.mean())


def run_policy(name, *, rounds: int = None, dataset: Optional[int] = None,
               base_budget=None, seed: int = 0, streamed: bool = False,
               env: EnvSpec = POOL_SPEC, reducer=None):
    """One run of a policy (name or spec) on ``env`` (an EnvSpec);
    ``streamed=True`` folds chunk logs through the engine's streaming
    reducer (``repro.engine.ReducerSink``) — host memory stays O(chunk)
    and the result is the reducer (a
    :class:`repro.engine.StreamingSummary`, or ``reducer`` when given —
    e.g. a :class:`repro.engine.StreamingHistogram`) instead of an
    :class:`ExperimentResult` (budgets then come from the streamed
    greedy reference too)."""
    from repro.engine import ReducerSink
    if base_budget is None and policy_mod.as_spec(name).budgeted:
        budget_of = ((lambda i: greedy_reference_streamed(i, seed,
                                                          env).avg_cost)
                     if streamed else
                     (lambda i: dataset_budget(i, seed, env)))
        num_ds = env.make_env().num_datasets
        if dataset is None and num_ds > 1:
            base_budget = np.asarray(
                [budget_of(i) for i in range(num_ds)], np.float32)
        else:
            base_budget = budget_of(dataset)
    t0 = time.perf_counter()
    res = router.run_pool_experiment(
        name, rounds=rounds or ROUNDS, seed=seed, dataset=dataset, env=env,
        base_budget=base_budget if base_budget is not None else 1e-3,
        sink=ReducerSink(reducer) if streamed else None)
    dt = time.perf_counter() - t0
    return res, dt


# -- streaming-reducer variants (no (T, H) arrays ever materialized) --------

_GREEDY_STREAM_CACHE: Dict[tuple, object] = {}


def greedy_reference_streamed(dataset: int, seed: int = 0,
                              env: EnvSpec = POOL_SPEC):
    """Streamed greedy-LinUCB reference: an
    :class:`repro.engine.StreamingSummary` folded chunk-by-chunk from the
    driver — doubles as a Table row and the budget reference
    (``avg_cost`` == the paper's greedy avg per-query cost protocol)."""
    from repro.engine import ReducerSink
    key = (env, dataset, seed)
    if key not in _GREEDY_STREAM_CACHE:
        _GREEDY_STREAM_CACHE[key] = router.run_pool_experiment(
            "greedy_linucb", rounds=ROUNDS, seed=seed, dataset=dataset,
            env=env, sink=ReducerSink())
    return _GREEDY_STREAM_CACHE[key]


def run_policy_streamed(name, **kwargs):
    """:func:`run_policy` with ``streamed=True`` (kept as a named entry
    point for the streaming aggregation path)."""
    return run_policy(name, streamed=True, **kwargs)


_GREEDY_SWEEP_CACHE: Dict[tuple, list] = {}


def greedy_reference_sweep(dataset: int, seeds=None,
                           env: EnvSpec = POOL_SPEC):
    """Multi-seed greedy-LinUCB reference runs for one dataset (cached).

    One vmapped program for all seeds; doubles as the Table-1 row and the
    per-seed budget reference (paper: budget = greedy's avg cost ±5%)."""
    seeds = tuple(range(SEEDS)) if seeds is None else tuple(seeds)
    key = (env, dataset, seeds)
    if key not in _GREEDY_SWEEP_CACHE:
        _GREEDY_SWEEP_CACHE[key] = router.run_pool_experiment_sweep(
            "greedy_linucb", list(seeds), rounds=ROUNDS, dataset=dataset,
            env=env)
    return _GREEDY_SWEEP_CACHE[key]


def dataset_budgets_sweep(dataset: int, seeds=None,
                          env: EnvSpec = POOL_SPEC) -> np.ndarray:
    """(S,) per-seed budgets: each seed's greedy reference mean cost."""
    return np.asarray([float(res.cost_per_round.mean())
                       for res in greedy_reference_sweep(dataset, seeds,
                                                         env)],
                      np.float32)


def run_policy_sweep(name, *, seeds=None, rounds: int = None,
                     dataset: Optional[int] = None, base_budget=None,
                     alpha: float = 0.675, env: EnvSpec = POOL_SPEC):
    """Vmapped multi-seed replications; returns (results_per_seed, secs).

    Budgeted policies default to the paper protocol budget — each seed's
    own greedy-LinUCB average cost per query on that dataset."""
    seeds = list(range(SEEDS)) if seeds is None else list(seeds)
    if base_budget is None and policy_mod.as_spec(name).budgeted:
        num_ds = env.make_env().num_datasets
        if dataset is None and num_ds > 1:
            base_budget = np.stack(
                [dataset_budgets_sweep(i, seeds, env)
                 for i in range(num_ds)], axis=1)  # (S, D)
        else:
            # (S, 1): per-seed budgets (1-D means per-dataset to the sweep)
            base_budget = dataset_budgets_sweep(dataset, seeds,
                                                env)[:, None]
    t0 = time.perf_counter()
    res = router.run_pool_experiment_sweep(
        name, seeds, rounds=rounds or ROUNDS, dataset=dataset, env=env,
        base_budget=base_budget if base_budget is not None else 1e-3,
        alpha=alpha)
    return res, time.perf_counter() - t0


def _is_greedy(name) -> bool:
    spec = policy_mod.as_spec(name)
    return spec.name == "greedy_linucb" and not spec.transforms \
        and not spec.args


def run_policy_sweep_per_dataset(name, *, seeds=None,
                                 env: EnvSpec = POOL_SPEC):
    """Paper protocol (one stream per benchmark dataset) × SEEDS seeds."""
    out = {}
    total = 0.0
    seeds = list(range(SEEDS)) if seeds is None else list(seeds)
    for i, ds in dataset_streams(env):
        if _is_greedy(name):
            t0 = time.perf_counter()
            res = greedy_reference_sweep(i, seeds, env)
            dt = time.perf_counter() - t0   # ~0 on later (cached) calls
        else:
            res, dt = run_policy_sweep(name, seeds=seeds, dataset=i,
                                       env=env)
        out[ds] = res
        total += dt
    return out, total


def run_policy_per_dataset(name, *, seed: int = 0, streamed: bool = False,
                           env: EnvSpec = POOL_SPEC):
    """Paper protocol: each benchmark dataset is its own stream (per-arm
    cost distributions are dataset-specific, matching Assumption 5).

    ``streamed=True`` aggregates every run through the engine's streaming
    reducer instead of materializing ``(T, H)`` result arrays — the
    entries are then :class:`repro.engine.StreamingSummary` objects
    (same accessor names for the Table-level statistics)."""
    out = {}
    total = 0.0
    for i, ds in dataset_streams(env):
        if streamed:
            if _is_greedy(name):
                res, dt = greedy_reference_streamed(i, seed, env), 0.0
            else:
                res, dt = run_policy_streamed(name, dataset=i, seed=seed,
                                              env=env)
        elif _is_greedy(name):
            res, dt = greedy_reference(i, seed, env), 0.0
        else:
            res, dt = run_policy(name, dataset=i, seed=seed, env=env)
        out[ds] = res
        total += dt
    return out, total


def per_dataset_accuracy(res) -> Dict[str, float]:
    out = {}
    for i, ds in enumerate(env_mod.DATASETS):
        mask = res.datasets == i
        if mask.sum():
            out[ds] = float((res.success_step[mask] > 0).mean())
    return out


def per_dataset_cost(res) -> Dict[str, float]:
    out = {}
    for i, ds in enumerate(env_mod.DATASETS):
        mask = res.datasets == i
        if mask.sum():
            out[ds] = float(res.cost_per_round[mask].mean())
    return out


def save_json(name: str, payload) -> str:
    path = os.path.join(ensure_dir(), f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    return path


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None
