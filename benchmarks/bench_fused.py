"""Fused round mega-kernel benchmark: one launch vs three per decision.

The sequential LinUCB decision loop is launch-bound at small d: each
round dispatches the blocked score kernel, an XLA argmax, and the
selected-arm Sherman–Morrison kernel — three dispatches whose combined
FLOPs take microseconds. ``kernels.fused_round`` collapses the whole
round into ONE ``pallas_call``. This suite times exactly that contrast
on the driver's state shapes:

* ``round_d64`` / ``round_d384`` — the per-decision latency of the
  three-launch sequence (score → argmax → update, one jitted dispatch
  each, the serving-loop shape) vs the fused single launch, at the
  dispatch-bound d=64 regime and the paper shape d=384. The headline
  claim: ≥ 2× rounds/s at d=64.
* ``driver_scan_d64`` — the end-to-end scan driver
  (``run_pool_experiment``) with ``fuse_rounds=`` off/on, plus a bitwise
  parity check of the full result logs. Inside one scanned XLA program
  the CPU interpret backend amortizes launches away, so this entry
  records throughput and parity rather than a speedup claim — per-launch
  overhead is what real TPU dispatch pays, and the round_* entries are
  its proxy.

All timings are warm; results land in results/benchmarks via
``common.save_json`` (→ ``bench_fused.json``).
"""
from __future__ import annotations

import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import env as env_mod
from repro.core import linucb
from repro.engine import driver
from repro.kernels import fused_round, linucb_score, sherman_morrison

ROUNDS = 2000
NUM_ARMS = 6
RESULT_FIELDS = ("arms", "rewards", "costs", "regrets", "budgets",
                 "datasets")


def _warm_state(d: int, seed: int = 0) -> linucb.LinUCBState:
    cfg = linucb.LinUCBConfig(num_arms=NUM_ARMS, dim=d)
    s = linucb.init(cfg)
    key = jax.random.PRNGKey(seed)
    for i in range(2 * NUM_ARMS):
        kx, kr, key = jax.random.split(key, 3)
        x = jax.random.normal(kx, (d,)) / np.sqrt(d)
        s = linucb.update(s, jnp.int32(i % NUM_ARMS), x,
                          jax.random.bernoulli(kr).astype(jnp.float32))
    return s


def _dispatch_loop(fn, state, x, n: int) -> float:
    """Seconds for ``n`` sequential dispatches of one decision round."""
    out = fn(state.a_inv_t, state.theta, x)
    jax.block_until_ready(out[0])
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(state.a_inv_t, state.theta, x)
    jax.block_until_ready(out[0])
    return time.perf_counter() - t0


def _round_compare(d: int) -> Dict[str, float]:
    """Three-launch vs fused-single-launch per-decision latency at d."""
    k = NUM_ARMS
    state = _warm_state(d)
    x = jax.random.normal(jax.random.PRNGKey(7), (d,)) / np.sqrt(d)
    feas = jnp.ones((k,), jnp.int32)
    lower = jnp.ones((k,), jnp.float32)
    mean_ext = jnp.zeros((k,), jnp.float32)
    interp = jax.default_backend() != "tpu"

    score_j = jax.jit(functools.partial(
        linucb_score.linucb_score_blocked, alpha=0.675, interpret=interp))
    argmax_j = jax.jit(
        lambda sc: jnp.argmax(sc, axis=-1).astype(jnp.int32))
    sm_j = jax.jit(functools.partial(
        sherman_morrison.sherman_morrison_arm, interpret=interp))
    fused_j = jax.jit(functools.partial(
        fused_round.fused_round_step, alpha=0.675, recompose=False,
        interpret=interp))

    def three_launch(a_inv_t, theta, xv):
        scores = score_j(xv[None], theta, a_inv_t)
        arm = argmax_j(scores)[0]
        a_new, ax = sm_j(a_inv_t, xv, arm, jnp.float32(1.0))
        return a_new, arm, ax

    def one_launch(a_inv_t, theta, xv):
        return fused_j(a_inv_t, theta, xv, feas, lower, mean_ext,
                       jnp.float32(1.0), jnp.float32(1.0))

    three_s = common.median_secs(
        lambda: _dispatch_loop(three_launch, state, x, ROUNDS))
    fused_s = common.median_secs(
        lambda: _dispatch_loop(one_launch, state, x, ROUNDS))
    return {
        "three_launch_s": three_s,
        "fused_s": fused_s,
        "three_launch_rounds_per_s": ROUNDS / three_s,
        "fused_rounds_per_s": ROUNDS / fused_s,
        "speedup": three_s / fused_s,
    }


def _driver_compare() -> Dict[str, object]:
    """End-to-end scan driver with ``fuse_rounds=`` off/on + parity."""
    env64 = env_mod.CalibratedPoolEnv(dim=64)
    backend = "pallas" if jax.default_backend() == "tpu" \
        else "pallas_interpret"
    with linucb.backend_scope(backend):
        runs = {}
        for fuse in (False, True):
            run = lambda: driver.run_pool_experiment(
                "greedy_linucb", rounds=ROUNDS, env=env64,
                fuse_rounds=fuse)
            run()                       # warm the jitted driver
            runs[fuse] = (common.median_secs(run), run())
        (unfused_s, res_a), (fused_s, res_b) = runs[False], runs[True]
    parity = all(np.array_equal(getattr(res_a, f), getattr(res_b, f))
                 for f in RESULT_FIELDS)
    return {
        "backend": backend,
        "unfused_s": unfused_s,
        "fused_s": fused_s,
        "unfused_rounds_per_s": ROUNDS / unfused_s,
        "fused_rounds_per_s": ROUNDS / fused_s,
        "ratio": unfused_s / fused_s,
        "bitwise_parity": parity,
    }


def run() -> Dict:
    out: Dict[str, object] = {"rounds": ROUNDS, "num_arms": NUM_ARMS}
    out["round_d64"] = _round_compare(64)
    out["round_d384"] = _round_compare(384)
    out["driver_scan_d64"] = _driver_compare()
    common.save_json("bench_fused", out)
    return out


def main():
    out = run()
    print("\n=== Fused round: one launch vs three per decision ===")
    for key in ("round_d64", "round_d384"):
        v = out[key]
        print(f"{key}: {v['fused_rounds_per_s']:.0f} rounds/s fused vs "
              f"{v['three_launch_rounds_per_s']:.0f} three-launch "
              f"({v['speedup']:.2f}x)")
    dv = out["driver_scan_d64"]
    print(f"driver_scan_d64[{dv['backend']}]: "
          f"{dv['fused_rounds_per_s']:.0f} rounds/s fused vs "
          f"{dv['unfused_rounds_per_s']:.0f} unfused "
          f"(parity={dv['bitwise_parity']})")
    claims = {
        "fused_2x_at_d64": out["round_d64"]["speedup"] >= 2.0,
        "fused_faster_at_d384": out["round_d384"]["speedup"] > 1.0,
        "driver_bitwise_parity": bool(dv["bitwise_parity"]),
    }
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
