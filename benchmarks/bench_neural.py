"""Neural-linear vs pure-linear LinUCB: regret / accuracy at matched cost.

The neural policies keep the posterior math on the same ``(d, K·d)``
block kernels as ``greedy_linucb`` — just at ``d = features`` over the
MLP trunk's learned representation — so the honest comparison is
accuracy and regret at the cost each router actually pays, plus the
per-decision overhead the trunk forward adds to scoring.

Entries:

* ``pipeline`` / ``pipeline_mix`` / ``calibrated_pool`` — mean accuracy,
  total regret, and avg cost per round for ``greedy_linucb`` (linear,
  d = raw context) vs ``neural_linucb`` (trunk + LinUCB head at
  d = features), over ``NEURAL_SEEDS`` vmapped seed replications each.
  The headline acceptance claim lives on the plain pipeline env:
  neural mean accuracy ≥ linear's, at matched (≤ +5%) cost.
* ``score_overhead`` — jitted per-decision scoring latency: the raw
  d=384 linear UCB launch vs trunk-forward + d=features UCB. Reports
  rounds/s for both and the multiplicative overhead of the MLP forward.

Results land in results/benchmarks via ``common.save_json``
(→ ``bench_neural.json``).
"""
from __future__ import annotations

import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import linucb
from repro.core.policy import PolicySpec
from repro.core.scenario import EnvSpec
from repro.neural import scorer as scorer_mod
from repro.neural.policy import resolve_configs

# the acceptance claim is a multi-seed mean — never fewer than 6 seeds,
# whatever REPRO_BENCH_SEEDS trims the table suites to
NEURAL_SEEDS = max(common.SEEDS, 6)
NEURAL_SPEC = PolicySpec.from_name("neural_linucb")
LINEAR_SPEC = PolicySpec.from_name("greedy_linucb")

COMPARE_ENVS = (
    ("pipeline", EnvSpec.from_name("pipeline")),
    ("pipeline_mix", EnvSpec.from_name("pipeline", num_datasets=4)),
    ("calibrated_pool", EnvSpec.from_name("calibrated_pool")),
)


def _sweep_stats(spec, env: EnvSpec) -> Dict[str, float]:
    seeds = list(range(NEURAL_SEEDS))
    res, secs = common.run_policy_sweep(spec, seeds=seeds, env=env)
    accs = [r.accuracy for r in res]
    regs = [float(r.regrets.sum()) for r in res]
    costs = [float(r.cost_per_round.mean()) for r in res]
    return {
        "accuracy_mean": float(np.mean(accs)),
        "accuracy_per_seed": [float(a) for a in accs],
        "regret_mean": float(np.mean(regs)),
        "avg_cost": float(np.mean(costs)),
        "seeds": len(seeds),
        "rounds": common.ROUNDS,
        "secs": secs,
        "rounds_per_s": common.ROUNDS * len(seeds) / max(secs, 1e-9),
    }


def _compare(env: EnvSpec) -> Dict[str, Dict[str, float]]:
    return {"linear": _sweep_stats(LINEAR_SPEC, env),
            "neural": _sweep_stats(NEURAL_SPEC, env)}


def _score_overhead(d: int = 384, k: int = 6, n: int = 2000) -> Dict:
    """Per-decision scoring latency: raw-d linear UCB vs MLP trunk
    forward + feature-d UCB (the neural path's extra work)."""
    scfg, bcfg, *_ = resolve_configs(NEURAL_SPEC, k, d)
    params = scorer_mod.init_params(scfg)
    lin_state = linucb.init(linucb.LinUCBConfig(num_arms=k, dim=d))
    neu_state = linucb.init(bcfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (d,)) / np.sqrt(d)

    lin_j = jax.jit(lambda s, xv: linucb.ucb_scores(s, xv, 0.675))
    neu_j = jax.jit(lambda p, s, xv: linucb.ucb_scores(
        s, scorer_mod.features(p, xv), 0.675))

    def loop(fn) -> float:
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn()
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    lin_s = common.median_secs(functools.partial(
        loop, lambda: lin_j(lin_state, x)))
    neu_s = common.median_secs(functools.partial(
        loop, lambda: neu_j(params, neu_state, x)))
    return {
        "d": d, "features": scfg.features, "num_arms": k, "calls": n,
        "linear_rounds_per_s": n / lin_s,
        "neural_rounds_per_s": n / neu_s,
        "mlp_overhead_ratio": neu_s / lin_s,
    }


def run() -> Dict:
    out: Dict[str, object] = {
        "neural_spec": NEURAL_SPEC.label,
        "linear_spec": LINEAR_SPEC.label,
    }
    for name, env in COMPARE_ENVS:
        out[name] = _compare(env)
    out["score_overhead"] = _score_overhead()
    common.save_json("bench_neural", out)
    return out


def main():
    out = run()
    print("\n=== Neural-linear vs linear LinUCB (accuracy at matched cost) ===")
    for name, _ in COMPARE_ENVS:
        lin, neu = out[name]["linear"], out[name]["neural"]
        print(f"{name}: neural acc {neu['accuracy_mean']:.4f} "
              f"(cost {neu['avg_cost']:.4f}) vs linear "
              f"{lin['accuracy_mean']:.4f} (cost {lin['avg_cost']:.4f}), "
              f"regret {neu['regret_mean']:.1f} vs {lin['regret_mean']:.1f}")
    ov = out["score_overhead"]
    print(f"score_overhead d={ov['d']}→F={ov['features']}: "
          f"{ov['neural_rounds_per_s']:.0f} rounds/s neural vs "
          f"{ov['linear_rounds_per_s']:.0f} linear "
          f"({ov['mlp_overhead_ratio']:.2f}x per decision)")

    pipe = out["pipeline"]
    claims = {
        # the ISSUE acceptance: neural beats plain greedy LinUCB on the
        # pipeline env's mean accuracy over >= 4 seed replications...
        "neural_beats_linear_pipeline":
            pipe["neural"]["accuracy_mean"] >= pipe["linear"]["accuracy_mean"]
            and pipe["neural"]["seeds"] >= 4,
        # ...at matched cost (the neural router may not buy accuracy by
        # systematically routing to pricier arms)
        "neural_cost_matched_pipeline":
            pipe["neural"]["avg_cost"] <= 1.05 * pipe["linear"]["avg_cost"],
    }
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    import sys
    _, _claims = main()
    if not all(_claims.values()):
        sys.exit(1)
