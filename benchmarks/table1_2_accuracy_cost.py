"""Paper Tables 1 & 2: accuracy (%) and cost (USD) of every policy vs the
six candidate LLMs, per benchmark dataset, on the calibrated pool env.

Claims validated (paper §6.1.1):
  * every proposed router beats the best single candidate LLM on average;
  * the knapsack heuristic has the best average accuracy of the three;
  * budget-aware LinUCB is the cheapest of the three (≈ MetaLLM's cost at
    much higher accuracy).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks import common
from repro.core import env as env_mod


def run() -> Dict:
    """Every (policy, dataset) entry is the mean over ``common.SEEDS``
    replications, run as one vmapped sweep per (policy, dataset). The
    row list is the spec-driven ``common.TABLE_CONFIGS`` —
    ``(EnvSpec, PolicySpec)`` pairs, not hardcoded names."""
    table_acc: Dict[str, Dict[str, float]] = {}
    table_cost: Dict[str, Dict[str, float]] = {}
    table_acc_sd: Dict[str, Dict[str, float]] = {}
    timings: Dict[str, float] = {}

    for env_spec, spec in common.TABLE_CONFIGS:
        per_ds, dt = common.run_policy_sweep_per_dataset(spec, env=env_spec)
        label = common.policy_label(spec)
        accs = {ds: [res.accuracy for res in sweep]
                for ds, sweep in per_ds.items()}
        costs = {ds: [float(res.cost_per_round.mean()) for res in sweep]
                 for ds, sweep in per_ds.items()}
        acc = {ds: float(np.mean(v)) for ds, v in accs.items()}
        acc_sd = {ds: float(np.std(v)) for ds, v in accs.items()}
        cost = {ds: float(np.mean(v)) for ds, v in costs.items()}
        acc["avg"] = sum(acc.values()) / len(acc)
        cost["avg"] = sum(cost.values()) / len(cost)
        table_acc[label] = acc
        table_acc_sd[label] = acc_sd
        table_cost[label] = cost
        timings[label] = dt

    payload = {"accuracy": table_acc, "accuracy_sd": table_acc_sd,
               "cost": table_cost, "timings_s": timings,
               "rounds": common.ROUNDS, "seeds": common.SEEDS}
    common.save_json("table1_2", payload)
    return payload


def check_claims(payload) -> Dict[str, bool]:
    acc = payload["accuracy"]
    cost = payload["cost"]
    best_single = max(acc[a]["avg"] for a in env_mod.ARM_NAMES)
    ours = {p: acc[p]["avg"] for p in common.OUR_POLICIES}
    return {
        "all_ours_beat_best_single": all(v > best_single
                                         for v in ours.values()),
        # paper: knapsack 74.8 vs greedy 72.0 — they are close; in the sim
        # we require knapsack within 3 pts of the best of ours AND cheaper
        # than (unbudgeted) greedy, which is the paper's efficiency story
        "knapsack_competitive_and_cheaper":
            ours["knapsack"] >= max(ours.values()) - 0.03
            and cost["knapsack"]["avg"] < cost["greedy_linucb"]["avg"],
        "budget_cheapest_of_ours":
            min(common.OUR_POLICIES,
                key=lambda p: cost[p]["avg"]) == "budget_linucb",
        "ours_beat_baseline_routers": all(
            ours[p] > max(acc["metallm"]["avg"], acc["mixllm"]["avg"])
            for p in ("greedy_linucb", "knapsack")),
    }


def main():
    payload = run()
    claims = check_claims(payload)
    print("\n=== Table 1 (accuracy, calibrated sim) ===")
    hdr = ["policy"] + list(env_mod.DATASETS) + ["avg"]
    print(",".join(hdr))
    for k, v in payload["accuracy"].items():
        print(",".join([k] + [f"{100*v.get(d, 0):.2f}"
                              for d in hdr[1:]]))
    print("\n=== Table 2 (cost USD) ===")
    for k, v in payload["cost"].items():
        print(",".join([k] + [f"{v.get(d, 0):.2e}" for d in hdr[1:]]))
    print("\nclaims:", claims)
    return payload, claims


if __name__ == "__main__":
    main()
