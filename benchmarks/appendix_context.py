"""Paper Appendix B: impact of context evolution.

Strategy 1: gemini-flash standalone; Strategy 2: mistral first, then
gemini WITH the failed attempt in context. The calibrated env implements
the measured +5pt context gain; claim: Strategy 2's success rate exceeds
Strategy 1's, at higher cost — and some queries succeed ONLY through the
context path.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import env as env_mod

MISTRAL, GEMINI = 0, 3
AIME = 1


def run(queries: int = 2000, seed: int = 0) -> Dict:
    env = env_mod.CalibratedPoolEnv()
    params = env.make(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)

    s1_hits, s2_hits, context_saves, context_hurts = 0, 0, 0, 0
    s1_cost, s2_cost = 0.0, 0.0
    for t in range(queries):
        kq, k1, k2, k3 = jax.random.split(jax.random.fold_in(key, t), 4)
        q = env.reset(params, kq, dataset=jnp.int32(AIME))
        # Strategy 1: gemini alone
        r1, c1, _ = env.step(params, k1, q, jnp.int32(GEMINI))
        s1_cost += float(c1)
        hit1 = float(r1) > 0.5
        s1_hits += hit1
        # Strategy 2: mistral first; on failure gemini sees the context
        rm, cm, q2 = env.step(params, k2, q, jnp.int32(MISTRAL))
        s2_cost += float(cm)
        if float(rm) > 0.5:
            hit2 = True
        else:
            rg, cg, _ = env.step(params, k3, q2, jnp.int32(GEMINI))
            s2_cost += float(cg)
            hit2 = float(rg) > 0.5
        s2_hits += hit2
        if hit2 and not hit1:
            context_saves += 1
        if hit1 and not hit2:
            context_hurts += 1

    out = {
        "strategy1_gemini_only": s1_hits / queries,
        "strategy2_mistral_then_gemini": s2_hits / queries,
        "context_driven_successes": context_saves,
        "context_losses": context_hurts,
        "cost1": s1_cost / queries,
        "cost2": s2_cost / queries,
        "queries": queries,
    }
    common.save_json("appendix_context", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    return {
        "context_improves_success":
            out["strategy2_mistral_then_gemini"]
            > out["strategy1_gemini_only"],
        "context_saves_exist": out["context_driven_successes"] > 0,
        "sequential_costs_more": out["cost2"] > out["cost1"],
    }


def main():
    out = run()
    print("\n=== Appendix B (context impact) ===")
    print(f"gemini-only: {100*out['strategy1_gemini_only']:.1f}% "
          f"@ ${out['cost1']:.2e}")
    print(f"mistral→gemini w/ context: "
          f"{100*out['strategy2_mistral_then_gemini']:.1f}% "
          f"@ ${out['cost2']:.2e}")
    print(f"context-driven successes: {out['context_driven_successes']}, "
          f"losses: {out['context_losses']}")
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
