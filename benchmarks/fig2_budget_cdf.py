"""Paper Figure 2: CDF of per-query cost vs the budget line.

Claim validated (§6.1.3): the budget-aware policies keep (nearly) all
queries under the budget, while unconstrained Greedy LinUCB's cost
distribution extends well past it.

Aggregation is streaming: every run folds its per-round costs through
the engine's :class:`~repro.engine.aggregate.StreamingHistogram` reducer
(one histogram per policy, shared across the four dataset streams), so
no ``(T, H)`` arrays are materialized — budget adherence is counted
exactly per round against each round's own logged budget (the paper's
dashed line; the streamed greedy-avg-cost protocol budget stands in for
unbudgeted greedy), percentiles come from the log-spaced bins. The row
list is spec-driven (``common.spec_pairs``).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks import common
from repro.engine import StreamingHistogram

CONFIGS = common.spec_pairs(*common.OUR_POLICIES)


def run() -> Dict:
    """Per-round cost vs that round's own budget, streamed. For
    unbudgeted greedy, the comparison line is the same per-dataset
    budget the others received (``StreamingHistogram.fallback_budget``)."""
    out: Dict[str, Dict] = {}
    for env_spec, spec in CONFIGS:
        name = common.policy_label(spec)
        hist = StreamingHistogram()
        t0 = time.perf_counter()
        for i, _ in common.dataset_streams(env_spec):
            # rounds with a non-finite logged budget (unbudgeted greedy)
            # are judged against the dataset's protocol budget line —
            # from the SAME env the run uses
            hist.fallback_budget = common.greedy_reference_streamed(
                i, env=env_spec).avg_cost
            common.run_policy(spec, dataset=i, streamed=True, env=env_spec,
                              reducer=hist)
        dt = time.perf_counter() - t0
        s = hist.summary()
        out[name] = {
            "within_budget_frac": s["within_budget_frac"],
            "p50": s["p50"], "p90": s["p90"], "p99": s["p99"],
            "max": s["max"],
            "cdf_x": [float(x) for x in
                      hist.quantile(np.arange(0, 101, 5))],
            "time_s": dt,
        }
    common.save_json("fig2_budget_cdf", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    return {
        "budget_aware_adheres":
            out["budget_linucb"]["within_budget_frac"] >= 0.95,
        "knapsack_disciplined":
            out["knapsack"]["within_budget_frac"] >= 0.90,
        "greedy_exceeds": out["greedy_linucb"]["within_budget_frac"]
            < out["budget_linucb"]["within_budget_frac"],
    }


def main():
    out = run()
    print("\n=== Fig 2 (per-query cost CDF vs budget, streamed) ===")
    print("policy,within_budget,p50,p90,p99,max")
    for k, v in out.items():
        print(f"{k},{100*v['within_budget_frac']:.1f}%,{v['p50']:.2e},"
              f"{v['p90']:.2e},{v['p99']:.2e},{v['max']:.2e}")
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
