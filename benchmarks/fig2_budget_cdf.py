"""Paper Figure 2: CDF of per-query cost vs the budget line.

Claim validated (§6.1.3): the budget-aware policies keep (nearly) all
queries under the budget, while unconstrained Greedy LinUCB's cost
distribution extends well past it.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks import common

def run() -> Dict:
    """Per-round cost vs that round's own budget (the paper's dashed
    line; budgets follow the greedy-avg-cost protocol, per dataset). For
    unbudgeted greedy, the comparison line is the same per-dataset budget
    the others received."""
    from repro.core import env as env_mod
    out: Dict[str, Dict] = {}
    for name in common.OUR_POLICIES:
        per_ds, dt = common.run_policy_per_dataset(name)
        costs, lines = [], []
        for i, ds in enumerate(env_mod.DATASETS):
            res = per_ds[ds]
            c = res.cost_per_round
            b = np.where(np.isfinite(res.budgets), res.budgets,
                         common.dataset_budget(i))
            costs.append(c)
            lines.append(b)
        costs = np.concatenate(costs)
        lines = np.concatenate(lines)
        qs = np.percentile(costs, [50, 90, 99, 100])
        out[name] = {
            "within_budget_frac": float((costs <= lines * 1.05).mean()),
            "p50": float(qs[0]), "p90": float(qs[1]),
            "p99": float(qs[2]), "max": float(qs[3]),
            "cdf_x": [float(x) for x in np.percentile(
                costs, np.arange(0, 101, 5))],
            "time_s": dt,
        }
    common.save_json("fig2_budget_cdf", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    return {
        "budget_aware_adheres":
            out["budget_linucb"]["within_budget_frac"] >= 0.95,
        "knapsack_disciplined":
            out["knapsack"]["within_budget_frac"] >= 0.90,
        "greedy_exceeds": out["greedy_linucb"]["within_budget_frac"]
            < out["budget_linucb"]["within_budget_frac"],
    }


def main():
    out = run()
    print("\n=== Fig 2 (per-query cost CDF vs budget) ===")
    print("policy,within_budget,p50,p90,p99,max")
    for k, v in out.items():
        print(f"{k},{100*v['within_budget_frac']:.1f}%,{v['p50']:.2e},"
              f"{v['p90']:.2e},{v['p99']:.2e},{v['max']:.2e}")
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
