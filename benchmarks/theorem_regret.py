"""Theorems 1 & 2: empirical myopic-regret curves on the exactly-linear
synthetic environment (Assumptions 1–5 hold by construction).

Validated: cumulative myopic regret is sublinear (log-log slope < 0.85,
√T-like), and stays under the Theorem 1 bound evaluated with the run's
(K, d, T, H, S, L).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks import common
from repro.core import linucb, router


def run(rounds: int = 1500) -> Dict:
    """Mean regret curves over ``common.SEEDS`` replications (one vmapped
    sweep per policy); the claims check the mean curve, per-seed slopes
    are recorded alongside."""
    seeds = list(range(common.SEEDS))
    out: Dict[str, Dict] = {}
    for policy in ("greedy_linucb", "budget_linucb"):
        res = router.run_synthetic_experiment_sweep(
            policy, seeds, rounds=rounds, num_arms=6, dim=16, horizon=4)
        cums = res["cumulative_regret"]                      # (S, T)
        cum = cums.mean(axis=0)
        slopes = [router.sublinearity_slope(c, burn_in=100) for c in cums]
        slope = router.sublinearity_slope(cum, burn_in=100)
        cfg = linucb.LinUCBConfig(num_arms=6, dim=16)
        bound = linucb.theorem1_bound(cfg, rounds, 4, 1.0, 1.0)
        out[policy] = {
            "seeds": len(seeds),
            "total_regret": float(cum[-1]),
            "total_regret_per_seed": [float(c[-1]) for c in cums],
            "loglog_slope": slope,
            "loglog_slope_per_seed": slopes,
            "theorem1_bound": bound,
            "under_bound": bool(max(c[-1] for c in cums) < bound),
            "curve_t": [int(t) for t in
                        np.linspace(1, rounds, 30, dtype=int)],
            "curve_regret": [float(cum[t - 1]) for t in
                             np.linspace(1, rounds, 30, dtype=int)],
        }
    common.save_json("theorem_regret", out)
    return out


def check_claims(out) -> Dict[str, bool]:
    return {
        "greedy_sublinear": out["greedy_linucb"]["loglog_slope"] < 0.85,
        "budget_sublinear": out["budget_linucb"]["loglog_slope"] < 0.9,
        "greedy_under_thm1_bound": out["greedy_linucb"]["under_bound"],
    }


def main():
    out = run()
    print("\n=== Theorem 1/2 (synthetic regret) ===")
    for k, v in out.items():
        print(f"{k}: total={v['total_regret']:.1f} "
              f"slope={v['loglog_slope']:.2f} bound={v['theorem1_bound']:.0f}")
    claims = check_claims(out)
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
