"""Fault-suite serving benchmark: bursty trace replay through the
fault-tolerant runtime, chaos vs no-fault at MATCHED traffic.

Replays one Markov-modulated (bursty) arrival trace twice through
:class:`repro.serving.runtime.ServingRuntime` over a warm 6-arm pool:

* **no-fault** — the seeded latency model only; the throughput/latency
  baseline.
* **chaos** — 20% timeouts, 5% transient errors, 10% dropped feedback,
  and a full outage window over the learned-best arm (the acceptance
  scenario: quarantine → reroute → probe → re-admission).

Records p50/p99 routing latency (wall-clock of the jitted scoring
dispatch), sustained user-rounds/s, and regret-under-faults vs the
no-fault baseline into ``bench_serving_faults.json``. Claims checked by
``benchmarks.run``: both runs drain every admitted request with ZERO
lost feedback, the outage arm completes a quarantine → re-admission
cycle, and chaos regret stays ≤ 1.5× the no-fault baseline.

Run: ``PYTHONPATH=src python -m benchmarks.bench_serving_faults``
"""
from __future__ import annotations

import os
from typing import Dict, Tuple

from benchmarks import common
from repro.serving.faults import (FaultSpec, SyntheticArmPool,
                                  bursty_arrivals)
from repro.serving.runtime import (HealthConfig, RetryPolicy,
                                   RuntimeConfig, ServingRuntime)
from repro.serving.scheduler import ArmSpec, BanditScheduler

NUM_ARMS, DIM = 6, 16
T_END = float(os.environ.get("REPRO_BENCH_SERVE_T", "40.0"))
RATE = float(os.environ.get("REPRO_BENCH_SERVE_RATE", "8.0"))
OUTAGE = (10.0, 22.0)
REGRET_RATIO_BOUND = 1.5


def _runtime(pool: SyntheticArmPool, spec: FaultSpec) -> ServingRuntime:
    arms = [ArmSpec(f"llm-{k}", None, float(pool.costs[k]))
            for k in range(NUM_ARMS)]
    scheduler = BanditScheduler(arms, dim=DIM, alpha=1.0)
    cfg = RuntimeConfig(
        max_queue=512, max_batch=32, timeout_s=0.25, deadline_s=10.0,
        ring_capacity=16,
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.05,
                          max_delay_s=0.5, max_reroutes=2),
        health=HealthConfig(window=16, fail_threshold=0.6, min_samples=6,
                            probe_interval_s=0.5))
    rt = ServingRuntime(scheduler, pool.arm_fns(), faults=spec,
                        config=cfg, oracle=pool.oracle)
    pool.warmup(scheduler, 512)
    return rt


def run() -> Tuple[Dict, Dict]:
    pool = SyntheticArmPool(NUM_ARMS, DIM, seed=1)
    times = bursty_arrivals(t_end=T_END, rate=RATE, seed=11)
    contexts = pool.contexts(len(times), seed=5)
    best = pool.best_arm_overall(contexts)

    specs = {
        "no_fault": FaultSpec(seed=7),
        "chaos": FaultSpec(seed=7, timeout_rate=0.2, error_rate=0.05,
                           drop_feedback_rate=0.1, spike_rate=0.02,
                           outages=((best, OUTAGE[0], OUTAGE[1]),)),
    }

    payload: Dict = {"trace": {"arrivals": len(times), "t_end_s": T_END,
                               "rate": RATE, "outage_arm": best,
                               "outage_window_s": list(OUTAGE)}}
    reports = {}
    for label, spec in specs.items():
        rt = _runtime(pool, spec)
        # warm the route/update programs so the latency percentiles
        # measure the steady state, not the first-dispatch compile
        rt.scheduler.route(contexts[:32],
                           arm_mask=rt.health.mask())
        rt.submit_trace(contexts, times)
        rep = rt.run()
        reports[label] = rep
        payload[label] = rep.summary()

    ratio = (reports["chaos"].regret
             / max(reports["no_fault"].regret, 1e-9))
    payload["regret_ratio"] = ratio
    payload["regret_ratio_bound"] = REGRET_RATIO_BOUND

    chaos = reports["chaos"]
    outage_kinds = {e.kind for e in chaos.health_events if e.arm == best}
    claims = {
        "drains_all_requests": all(r.drained for r in reports.values()),
        "zero_lost_feedback": all(r.lost_feedback == 0
                                  for r in reports.values()),
        "outage_arm_quarantined_and_readmitted":
            {"quarantine", "readmit"} <= outage_kinds,
        "regret_under_faults_within_bound": ratio <= REGRET_RATIO_BOUND,
    }
    return payload, claims


def main():
    payload, claims = run()
    common.save_json("bench_serving_faults", payload)
    print("\n=== Serving under faults (bursty trace replay) ===")
    for label in ("no_fault", "chaos"):
        s = payload[label]
        print(f"{label:9s} served {s['served']}/{s['admitted']} "
              f"failed={s['failed']} lost_fb={s['lost_feedback']} "
              f"route p50/p99 = {s['route_p50_ms']:.2f}/"
              f"{s['route_p99_ms']:.2f} ms  "
              f"{s['user_rounds_per_s']:.0f} rounds/s  "
              f"regret={s['regret']:.1f}")
    print(f"regret ratio (chaos / no-fault) = "
          f"{payload['regret_ratio']:.2f}x "
          f"(bound {REGRET_RATIO_BOUND}x)")
    print("claims:", claims)
    return payload, claims


if __name__ == "__main__":
    _, _claims = main()
    if not all(_claims.values()):
        raise SystemExit(1)
