"""Kernel micro-benchmarks.

On CPU the production path is the jitted jnp reference (Pallas interpret
mode is a correctness harness, not a perf path), so we time the jitted
reference implementations at production-relevant shapes and report the
per-call latency of the routing hot loop.

The ``pallas_*`` section compares the native ``(d, K·d)`` block-layout
kernels against the legacy ``(K, d, d)`` entry points, both in interpret
mode: the legacy wrappers pay the transpose round-trip the pre-PR hot
path paid on every call, and the legacy single-arm update rewrites all K
inverses. The structural win is ``pallas_update_layout_speedup``
(O(K·d²) → O(d²), ~8× at K=6 — asserted ≥ 2 by the health check); the
score/batch legs only shed a transpose from ~10 ms of interpret-mode
work, so they hover at parity within this container's ±40% timing noise.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import router
from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 20, repeats: int = 3) -> float:
    """Best-of-``repeats`` mean latency (µs) — min over repeats rejects
    scheduler noise that a single pass happily reports as ±20%."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def run() -> Dict[str, float]:
    key = jax.random.PRNGKey(0)
    out: Dict[str, float] = {}

    # routing hot loop: B=128 concurrent queries × K=6 arms, d=384
    b, k, d = 128, 6, 384
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, d))
    theta = jax.random.normal(ks[1], (k, d))
    a = jax.random.normal(ks[2], (k, d, d))
    a_inv = jnp.einsum("kde,kfe->kdf", a, a) / d + jnp.eye(d)[None]
    score = jax.jit(lambda x, t, ai: ref.linucb_score_ref(x, t, ai, 0.675))
    out["linucb_score_B128_K6_d384"] = _time(score, x, theta, a_inv)

    xv = jax.random.normal(key, (d,))
    mask = jnp.zeros(k).at[2].set(1.0)
    sm = jax.jit(ref.sherman_morrison_ref)
    out["sherman_morrison_K6_d384"] = _time(sm, a_inv, xv, mask)

    bsz = 64
    xs_b = jax.random.normal(ks[1], (bsz, d))
    masks_b = jax.nn.one_hot(jax.random.randint(ks[2], (bsz,), 0, k), k)
    smb = jax.jit(ref.sherman_morrison_batch_ref)
    out[f"sherman_morrison_batch_B{bsz}_K6_d384"] = _time(
        smb, a_inv, xs_b, masks_b, iters=5)

    # native (d, K·d) Pallas kernels vs the legacy (K,d,d) entry points
    # (interpret mode on CPU — the real block algorithm as traced ops)
    a_inv_t = ref.pack_block(a_inv)
    out["pallas_native_score_B128_K6_d384"] = _time(
        ops.linucb_score_blocked, x, theta, a_inv_t, 0.675, iters=10,
        repeats=5)
    out["pallas_kdd_score_B128_K6_d384"] = _time(
        ops.linucb_score, x, theta, a_inv, 0.675, iters=10, repeats=5)
    out["pallas_score_layout_speedup"] = (
        out["pallas_kdd_score_B128_K6_d384"]
        / out["pallas_native_score_B128_K6_d384"])

    arm_j = jnp.int32(2)
    out["pallas_native_update_arm_K6_d384"] = _time(
        ops.sherman_morrison_arm, a_inv_t, xv, arm_j, jnp.float32(1.0),
        iters=5)
    out["pallas_kdd_update_K6_d384"] = _time(
        ops.sherman_morrison, a_inv, xv, mask, iters=5)
    out["pallas_update_layout_speedup"] = (
        out["pallas_kdd_update_K6_d384"]
        / out["pallas_native_update_arm_K6_d384"])

    out[f"pallas_native_batch_B{bsz}_K6_d384"] = _time(
        ops.sherman_morrison_batch_blocked, a_inv_t, xs_b, masks_b, iters=3)
    out[f"pallas_kdd_batch_B{bsz}_K6_d384"] = _time(
        ops.sherman_morrison_batch, a_inv, xs_b, masks_b, iters=3)
    out["pallas_batch_layout_speedup"] = (
        out[f"pallas_kdd_batch_B{bsz}_K6_d384"]
        / out[f"pallas_native_batch_B{bsz}_K6_d384"])

    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v,
                                                         causal=True))
    out["attention_ref_S1024_H8"] = _time(fa, q, kk, v, iters=5)

    # scanned experiment driver (rounds/sec at the paper shape) vs the
    # legacy per-round dispatch loop — the end-to-end hot path the
    # kernels above serve. Equal round counts, shared median-of-3 timing
    # (common.median_secs); benchmarks/bench_driver.py holds the full
    # comparison matrix.
    rounds = 256
    for policy in ("greedy_linucb", "budget_linucb"):
        run_scan = lambda: router.run_pool_experiment(
            policy, rounds=rounds, dispatch="scan")
        run_pr = lambda: router.run_pool_experiment(
            policy, rounds=rounds, dispatch="per_round")
        run_scan()   # warm the cached jitted drivers
        run_pr()
        scan_rps = rounds / common.median_secs(run_scan)
        pr_rps = rounds / common.median_secs(run_pr)
        out[f"driver_scan_rounds_per_s_{policy}"] = scan_rps
        out[f"driver_per_round_rounds_per_s_{policy}"] = pr_rps
        out[f"driver_scan_speedup_{policy}"] = scan_rps / pr_rps

    common.save_json("bench_kernels", out)
    return out


def main():
    out = run()
    print("\n=== Kernel micro-benchmarks (jitted reference path, CPU) ===")
    for name, v in out.items():
        if "speedup" in name:
            print(f"{name},{v:.2f}x")
        elif name.startswith("driver_"):
            print(f"{name},{v:.1f}rounds/s")
        else:
            print(f"{name},{v:.1f}us")
    return out, {"all_finite": all(v > 0 for v in out.values()),
                 "update_layout_win": out["pallas_update_layout_speedup"] >= 2.0}


if __name__ == "__main__":
    main()
