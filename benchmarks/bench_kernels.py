"""Kernel micro-benchmarks.

On CPU the production path is the jitted jnp reference (Pallas interpret
mode is a correctness harness, not a perf path), so we time the jitted
reference implementations at production-relevant shapes and report the
per-call latency of the routing hot loop.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import router
from repro.kernels import ref


def _time(fn, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # µs


def run() -> Dict[str, float]:
    key = jax.random.PRNGKey(0)
    out: Dict[str, float] = {}

    # routing hot loop: B=128 concurrent queries × K=6 arms, d=384
    b, k, d = 128, 6, 384
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, d))
    theta = jax.random.normal(ks[1], (k, d))
    a = jax.random.normal(ks[2], (k, d, d))
    a_inv = jnp.einsum("kde,kfe->kdf", a, a) / d + jnp.eye(d)[None]
    score = jax.jit(lambda x, t, ai: ref.linucb_score_ref(x, t, ai, 0.675))
    out["linucb_score_B128_K6_d384"] = _time(score, x, theta, a_inv)

    xv = jax.random.normal(key, (d,))
    mask = jnp.zeros(k).at[2].set(1.0)
    sm = jax.jit(ref.sherman_morrison_ref)
    out["sherman_morrison_K6_d384"] = _time(sm, a_inv, xv, mask)

    bsz = 64
    xs_b = jax.random.normal(ks[1], (bsz, d))
    masks_b = jax.nn.one_hot(jax.random.randint(ks[2], (bsz,), 0, k), k)
    smb = jax.jit(ref.sherman_morrison_batch_ref)
    out[f"sherman_morrison_batch_B{bsz}_K6_d384"] = _time(
        smb, a_inv, xs_b, masks_b, iters=5)

    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.float32)
    kk = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v,
                                                         causal=True))
    out["attention_ref_S1024_H8"] = _time(fa, q, kk, v, iters=5)

    # scanned experiment driver (rounds/sec at the paper shape) vs the
    # legacy per-round dispatch loop — the end-to-end hot path the
    # kernels above serve. Equal round counts, shared median-of-3 timing
    # (common.median_secs); benchmarks/bench_driver.py holds the full
    # comparison matrix.
    rounds = 256
    for policy in ("greedy_linucb", "budget_linucb"):
        run_scan = lambda: router.run_pool_experiment(
            policy, rounds=rounds, dispatch="scan")
        run_pr = lambda: router.run_pool_experiment(
            policy, rounds=rounds, dispatch="per_round")
        run_scan()   # warm the cached jitted drivers
        run_pr()
        scan_rps = rounds / common.median_secs(run_scan)
        pr_rps = rounds / common.median_secs(run_pr)
        out[f"driver_scan_rounds_per_s_{policy}"] = scan_rps
        out[f"driver_per_round_rounds_per_s_{policy}"] = pr_rps
        out[f"driver_scan_speedup_{policy}"] = scan_rps / pr_rps

    common.save_json("bench_kernels", out)
    return out


def main():
    out = run()
    print("\n=== Kernel micro-benchmarks (jitted reference path, CPU) ===")
    for name, v in out.items():
        if name.startswith("driver_"):
            unit = "x" if "speedup" in name else "rounds/s"
            print(f"{name},{v:.1f}{unit}")
        else:
            print(f"{name},{v:.1f}us")
    return out, {"all_finite": all(v > 0 for v in out.values())}


if __name__ == "__main__":
    main()
