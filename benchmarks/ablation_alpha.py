"""Beyond-paper ablation: sensitivity to the exploration parameter α.

The paper fixes α = 0.675 with no sweep. This ablation runs Greedy
LinUCB across α on the calibrated pool (mixed stream) to check the
choice isn't a cliff. Not part of ``benchmarks.run`` (extra study).
"""
from __future__ import annotations

from benchmarks import common
from repro.core import router

ALPHAS = (0.0, 0.1, 0.3, 0.675, 1.0, 2.0)


def run(rounds: int = 300) -> dict:
    out = {}
    for a in ALPHAS:
        res = router.run_pool_experiment("greedy_linucb", rounds=rounds,
                                         seed=0, alpha=a)
        out[f"{a:g}"] = {"accuracy": res.accuracy,
                         "regret": float(res.cumulative_regret[-1])}
    common.save_json("ablation_alpha", out)
    return out


def main():
    out = run()
    print("\n=== Ablation: exploration parameter α (greedy LinUCB) ===")
    print("alpha,accuracy,total_regret")
    for a, v in out.items():
        print(f"{a},{100*v['accuracy']:.1f},{v['regret']:.1f}")
    claims = {"paper_alpha_not_a_cliff":
              abs(out["0.675"]["accuracy"] - out["0.3"]["accuracy"]) < 0.1,
              "pure_exploit_worse_regret":
              out["0"]["regret"] >= out["0.675"]["regret"] * 0.8}
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
