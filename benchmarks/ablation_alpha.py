"""Beyond-paper ablation: sensitivity to the exploration parameter α.

The paper fixes α = 0.675 with no sweep. This ablation runs Greedy
LinUCB across α on the calibrated pool (mixed stream) to check the
choice isn't a cliff. Not part of ``benchmarks.run`` (extra study).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import router

ALPHAS = (0.0, 0.1, 0.3, 0.675, 1.0, 2.0)


def run(rounds: int = 300) -> dict:
    """Each α entry = mean over ``common.SEEDS`` vmapped replications."""
    seeds = list(range(common.SEEDS))
    out = {}
    for a in ALPHAS:
        sweep = router.run_pool_experiment_sweep(
            "greedy_linucb", seeds, rounds=rounds, alpha=a)
        out[f"{a:g}"] = {
            "accuracy": float(np.mean([r.accuracy for r in sweep])),
            "accuracy_sd": float(np.std([r.accuracy for r in sweep])),
            "regret": float(np.mean([r.cumulative_regret[-1]
                                     for r in sweep])),
        }
    common.save_json("ablation_alpha", out)
    return out


def main():
    out = run()
    print("\n=== Ablation: exploration parameter α (greedy LinUCB) ===")
    print("alpha,accuracy,total_regret")
    for a, v in out.items():
        print(f"{a},{100*v['accuracy']:.1f},{v['regret']:.1f}")
    claims = {"paper_alpha_not_a_cliff":
              abs(out["0.675"]["accuracy"] - out["0.3"]["accuracy"]) < 0.1,
              "pure_exploit_worse_regret":
              out["0"]["regret"] >= out["0.675"]["regret"] * 0.8}
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
