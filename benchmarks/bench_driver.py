"""Experiment-engine throughput benchmarks (driver, not kernels).

Times the device-resident chunked-``lax.scan`` driver against the legacy
one-jitted-call-per-round loop (``dispatch="per_round"``), plus the
vmapped multi-seed sweep against sequential per-round replications, at
three regimes:

``--multistream-regret`` records the statistical price of multi-stream
batching: total myopic regret of ``run_pool_multistream`` (frozen
per-round posterior snapshot, one batched fold) vs the per-step-updating
single-stream driver, across stream widths, at a fixed total user-round
count (→ ``bench_driver_multistream_regret.json``).

``--sharded`` runs the seeds × streams scaling suite instead: the
``shard_map``-sharded seed sweep vs the single-device vmapped sweep, and
the multi-stream engine at several stream widths, on 8 forced host
devices (the process re-execs itself with
``--xla_force_host_platform_device_count=8`` when needed — the flag must
precede jax init). Scaling efficiency (speedup / devices) lands in the
bench trajectory JSON as ``bench_driver_sharded``.

* ``pool_d384`` — the paper shape (K=6 arms, d=384). The round body is
  memory-bound on the (d, K·d) LinUCB inverse here, so the scan's win is
  the dispatch+transfer overhead plus in-place carry updates.
* ``pool_d64`` — a dispatch-bound pool (d=64): per-round host round-trips
  dominate the legacy path, which is where the device-resident engine
  shines (the production regime: cheap per-decision compute, huge T).
* ``synthetic_d16`` — the Theorem-1/2 driver at its default d=16.

All timings are warm (drivers compile once via the router's cached jit
programs; the first call of each config pays it, then we measure).
Results land in the bench trajectory via ``common.save_json``.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import Dict

import numpy as np

from benchmarks import common
from repro.core import env as env_mod
from repro.core import router

ROUNDS = 2000
SWEEP_SEEDS = 6
SHARD_DEVICES = 8
SHARD_SEEDS = 8
SHARD_ROUNDS = 500
STREAM_WIDTHS = (1, 8, 32)
MS_REGRET_WIDTHS = (1, 4, 16, 64)
MS_REGRET_USER_ROUNDS = 4096


def _timed(fn) -> float:
    return common.median_secs(fn)


def _compare(run_scan, run_per_round, rounds: int) -> Dict[str, float]:
    run_scan()          # warm (compile) the scanned driver
    run_per_round()     # warm the per-round driver
    scan_s = _timed(run_scan)
    per_round_s = _timed(run_per_round)
    return {
        "per_round_s": per_round_s,
        "scan_s": scan_s,
        "per_round_rounds_per_s": rounds / per_round_s,
        "scan_rounds_per_s": rounds / scan_s,
        "speedup": per_round_s / scan_s,
    }


def _verify_equivalence(rounds: int = 96) -> bool:
    for name in router.POLICIES:
        a = router.run_pool_experiment(name, rounds=rounds, seed=7,
                                       dispatch="per_round")
        b = router.run_pool_experiment(name, rounds=rounds, seed=7,
                                       dispatch="scan")
        for field in ("arms", "rewards", "costs", "regrets", "budgets",
                      "datasets"):
            if not np.array_equal(getattr(a, field), getattr(b, field)):
                return False
    return True


def run() -> Dict:
    out: Dict[str, object] = {"rounds": ROUNDS,
                              "scan_equals_per_round": _verify_equivalence()}

    for policy in ("greedy_linucb", "budget_linucb"):
        out[f"pool_d384_{policy}"] = _compare(
            lambda: router.run_pool_experiment(policy, rounds=ROUNDS,
                                               dispatch="scan"),
            lambda: router.run_pool_experiment(policy, rounds=ROUNDS,
                                               dispatch="per_round"),
            ROUNDS)

    env64 = env_mod.CalibratedPoolEnv(dim=64)
    out["pool_d64_greedy_linucb"] = _compare(
        lambda: router.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                           env=env64, dispatch="scan"),
        lambda: router.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                           env=env64, dispatch="per_round"),
        ROUNDS)

    # the pipeline-of-subtasks scenario (Atalar et al.) through the
    # env-generic engine: scan vs per_round, plus a vmapped sweep and a
    # multi-stream run — all four dispatch shapes on a non-pool env
    envp = env_mod.PipelineEnv(dim=64)
    out["pipeline_d64_greedy_linucb"] = _compare(
        lambda: router.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                           env=envp, dispatch="scan"),
        lambda: router.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                           env=envp, dispatch="per_round"),
        ROUNDS)
    pipe_seeds = list(range(4))
    router.run_pool_experiment_sweep("greedy_linucb", pipe_seeds,
                                     rounds=ROUNDS, env=envp)
    pipe_sweep_s = _timed(lambda: router.run_pool_experiment_sweep(
        "greedy_linucb", pipe_seeds, rounds=ROUNDS, env=envp))
    router.run_pool_multistream("greedy_linucb", rounds=ROUNDS // 8,
                                streams=8, env=envp)
    pipe_ms_s = _timed(lambda: router.run_pool_multistream(
        "greedy_linucb", rounds=ROUNDS // 8, streams=8, env=envp))
    out["pipeline_d64_sweep4_multistream8"] = {
        "seeds": len(pipe_seeds),
        "vmapped_sweep_s": pipe_sweep_s,
        "sweep_seed_rounds_per_s": len(pipe_seeds) * ROUNDS / pipe_sweep_s,
        "multistream_user_rounds_per_s": ROUNDS / pipe_ms_s,
    }

    out["synthetic_d16_greedy_linucb"] = _compare(
        lambda: router.run_synthetic_experiment("greedy_linucb",
                                                rounds=ROUNDS,
                                                dispatch="scan"),
        lambda: router.run_synthetic_experiment("greedy_linucb",
                                                rounds=ROUNDS,
                                                dispatch="per_round"),
        ROUNDS)

    # multi-seed replication workload: S sequential per-round experiments
    # (the only option before the engine) vs ONE vmapped scan sweep. The
    # sequential cost is S × one timed run — the replications are
    # independent and the driver is warm, so the extrapolation is exact
    # up to noise.
    seeds = list(range(SWEEP_SEEDS))
    router.run_pool_experiment_sweep("greedy_linucb", seeds, rounds=ROUNDS,
                                     env=env64)
    sweep_s = _timed(lambda: router.run_pool_experiment_sweep(
        "greedy_linucb", seeds, rounds=ROUNDS, env=env64))
    one_per_round = _timed(lambda: router.run_pool_experiment(
        "greedy_linucb", rounds=ROUNDS, env=env64, dispatch="per_round"))
    out["pool_d64_sweep6_greedy_linucb"] = {
        "seeds": SWEEP_SEEDS,
        "per_round_sequential_s": one_per_round * SWEEP_SEEDS,
        "vmapped_sweep_s": sweep_s,
        "sweep_seed_rounds_per_s": SWEEP_SEEDS * ROUNDS / sweep_s,
        "speedup": one_per_round * SWEEP_SEEDS / sweep_s,
    }

    # the theorem_regret workload: S replicated synthetic regret curves
    synth_seeds = list(range(8))
    router.run_synthetic_experiment_sweep("greedy_linucb", synth_seeds,
                                          rounds=ROUNDS)
    synth_sweep_s = _timed(lambda: router.run_synthetic_experiment_sweep(
        "greedy_linucb", synth_seeds, rounds=ROUNDS))
    synth_one_pr = _timed(lambda: router.run_synthetic_experiment(
        "greedy_linucb", rounds=ROUNDS, dispatch="per_round"))
    out["synthetic_d16_sweep8_greedy_linucb"] = {
        "seeds": len(synth_seeds),
        "per_round_sequential_s": synth_one_pr * len(synth_seeds),
        "vmapped_sweep_s": synth_sweep_s,
        "sweep_seed_rounds_per_s": len(synth_seeds) * ROUNDS / synth_sweep_s,
        "speedup": synth_one_pr * len(synth_seeds) / synth_sweep_s,
    }

    common.save_json("bench_driver", out)
    return out


def run_sharded() -> Dict:
    """Seeds × streams scaling suite (requires the forced host devices)."""
    import jax

    ndev = len(jax.devices())
    env64 = env_mod.CalibratedPoolEnv(dim=64)
    seeds = list(range(SHARD_SEEDS))
    # forced host devices timeshare the real cores, so scaling efficiency
    # on an oversubscribed CPU box mostly measures dispatch overhead —
    # record the core count so the number is interpretable (the win
    # materializes on real multi-chip meshes; parity is what CPU proves)
    out: Dict[str, object] = {"devices": ndev, "rounds": SHARD_ROUNDS,
                              "host_cores": os.cpu_count()}

    # sharded seed sweep vs single-device vmap, same program otherwise
    def vmapped():
        return router.run_pool_experiment_sweep(
            "greedy_linucb", seeds, rounds=SHARD_ROUNDS, env=env64,
            shard=False)

    def sharded():
        return router.run_pool_experiment_sweep(
            "greedy_linucb", seeds, rounds=SHARD_ROUNDS, env=env64,
            shard=True)

    a, b = vmapped(), sharded()      # warm both compiled programs
    parity = all(
        np.array_equal(getattr(x, f), getattr(y, f))
        for x, y in zip(a, b)
        for f in ("arms", "rewards", "costs", "regrets", "budgets",
                  "datasets"))
    vmap_s = _timed(vmapped)
    shard_s = _timed(sharded)
    speedup = vmap_s / shard_s
    out["seed_sweep"] = {
        "seeds": SHARD_SEEDS,
        "vmap_s": vmap_s,
        "shard_s": shard_s,
        "speedup": speedup,
        "scaling_efficiency": speedup / ndev,
        "shard_equals_vmap": parity,
        "seed_rounds_per_s": SHARD_SEEDS * SHARD_ROUNDS / shard_s,
    }

    # multi-stream engine: user-rounds/s at several stream widths (one
    # shared posterior; width 1 is the batching-free reference). Streams
    # run UNsharded here: a per-round shard_map on timeshared host
    # devices pays cross-device dispatch every round for no real
    # parallelism — stream sharding is for real multi-chip meshes.
    streams_out: Dict[str, object] = {}
    base_rps = None
    for b_width in STREAM_WIDTHS:
        def ms(b_width=b_width):
            return router.run_pool_multistream(
                "greedy_linucb", rounds=SHARD_ROUNDS, streams=b_width,
                env=env64, shard="none")
        ms()
        secs = _timed(ms)
        rps = SHARD_ROUNDS * b_width / secs
        base_rps = base_rps or rps
        streams_out[f"streams_{b_width}"] = {
            "seconds": secs,
            "user_rounds_per_s": rps,
            "throughput_vs_streams_1": rps / base_rps,
        }
    out["multistream"] = streams_out
    common.save_json("bench_driver_sharded", out)
    return out


def run_multistream_regret() -> Dict:
    """The regret cost of multi-stream batching (the batched-bandit angle).

    ``run_pool_multistream`` plays B streams per round against a FROZEN
    posterior snapshot and folds their observations once per round —
    standard delayed-feedback batching. The delay costs statistical
    efficiency: within a round no stream benefits from the others'
    observations. This suite quantifies that cost across stream widths B
    at a fixed total user-round count, against the per-step-updating
    single-stream driver as the reference — the throughput numbers in
    ``--sharded`` only mean anything alongside this regret price.
    """
    policies = ("greedy_linucb", "positional_linucb")
    env64 = env_mod.CalibratedPoolEnv(dim=64)
    total = MS_REGRET_USER_ROUNDS
    out: Dict[str, object] = {"user_rounds": total,
                              "stream_widths": list(MS_REGRET_WIDTHS)}
    for policy in policies:
        ref = router.run_pool_experiment(policy, rounds=total, env=env64,
                                         seed=0)
        ref_regret = float(ref.cumulative_regret[-1])
        entry: Dict[str, object] = {
            "per_step_reference": {
                "total_regret": ref_regret,
                "regret_per_round": ref_regret / total,
                "accuracy": ref.accuracy,
            }
        }
        for b in MS_REGRET_WIDTHS:
            res = router.run_pool_multistream(policy, rounds=total // b,
                                              streams=b, env=env64, seed=0)
            reg = float(res.cumulative_regret[-1])
            entry[f"streams_{b}"] = {
                "dispatch_rounds": total // b,
                "total_regret": reg,
                "regret_per_round": reg / total,
                "accuracy": res.accuracy,
                "regret_vs_per_step": reg / max(ref_regret, 1e-9),
            }
        out[policy] = entry
    common.save_json("bench_driver_multistream_regret", out)
    return out


def main_multistream_regret() -> int:
    out = run_multistream_regret()
    print(f"\n=== Multi-stream regret cost (frozen-snapshot fold vs "
          f"per-step updates, {out['user_rounds']} user rounds) ===")
    for policy, entry in out.items():
        if not isinstance(entry, dict):
            continue
        ref = entry["per_step_reference"]
        print(f"{policy}: per-step reference regret "
              f"{ref['total_regret']:.1f} "
              f"(acc {100 * ref['accuracy']:.1f}%)")
        for b in out["stream_widths"]:
            v = entry[f"streams_{b}"]
            print(f"  B={b:3d}: regret {v['total_regret']:.1f} "
                  f"({v['regret_vs_per_step']:.2f}x per-step, "
                  f"acc {100 * v['accuracy']:.1f}%)")
    return 0


def _reexec_with_devices() -> int:
    """Re-spawn under the forced-host-device flag (pre-jax-init only).

    Replays the EXACT invocation mode that reached us (``-m`` with the
    resolved module name, or the script path from argv) with only the
    environment changed, so whatever launch worked the first time works
    in the child too."""
    from repro.xla_flags import with_host_device_count

    env = dict(os.environ)
    env["XLA_FLAGS"] = with_host_device_count(env.get("XLA_FLAGS", ""),
                                              SHARD_DEVICES)
    spec = getattr(sys.modules.get("__main__"), "__spec__", None)
    if spec is not None and spec.name:
        cmd = [sys.executable, "-m", spec.name] + sys.argv[1:]
    else:
        cmd = [sys.executable] + sys.argv
    return subprocess.call(cmd, env=env)


def main_sharded() -> int:
    import jax

    if len(jax.devices()) < 2:
        from repro.xla_flags import HOST_DEVICE_FLAG

        # the flag only multiplies CPU host devices — if it is already
        # set and we still see one device (e.g. a GPU backend won), a
        # re-exec would recurse forever
        if HOST_DEVICE_FLAG in os.environ.get("XLA_FLAGS", ""):
            print("bench_driver --sharded: forced host devices had no "
                  f"effect (backend {jax.default_backend()!r} has "
                  f"{len(jax.devices())} device); aborting",
                  file=sys.stderr)
            return 1
        return _reexec_with_devices()
    out = run_sharded()
    sw = out["seed_sweep"]
    print(f"\n=== Sharded sweep: {sw['seeds']} seeds × "
          f"{out['devices']} devices ===")
    print(f"shard == vmap: {sw['shard_equals_vmap']}")
    print(f"speedup {sw['speedup']:.2f}x "
          f"(efficiency {sw['scaling_efficiency']:.2f}); "
          f"{sw['seed_rounds_per_s']:.0f} seed-rounds/s")
    for name, v in out["multistream"].items():
        print(f"{name}: {v['user_rounds_per_s']:.0f} user-rounds/s "
              f"({v['throughput_vs_streams_1']:.1f}x vs streams_1)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="run the seeds × streams scaling suite on "
                         f"{SHARD_DEVICES} forced host devices")
    ap.add_argument("--multistream-regret", action="store_true",
                    help="record the regret cost of the multi-stream "
                         "frozen-snapshot fold vs per-step updates "
                         f"across stream widths {MS_REGRET_WIDTHS}")
    args = ap.parse_args()
    if args.sharded:
        return sys.exit(main_sharded())
    if args.multistream_regret:
        return sys.exit(main_multistream_regret())
    out = run()
    print("\n=== Driver throughput: scanned engine vs per-round loop ===")
    print(f"scan == per_round (all policies): "
          f"{out['scan_equals_per_round']}")
    for key, v in out.items():
        if not isinstance(v, dict) or "speedup" not in v:
            continue
        print(f"{key}: speedup {v['speedup']:.1f}x "
              f"(scan {v.get('scan_s', v.get('vmapped_sweep_s')):.2f}s vs "
              f"per_round {v.get('per_round_s', v.get('per_round_sequential_s')):.2f}s)")
    claims = {
        "scan_equals_per_round": bool(out["scan_equals_per_round"]),
        "scan_faster_everywhere": all(
            v["speedup"] > 1.0 for v in out.values()
            if isinstance(v, dict) and "speedup" in v),
        "engine_10x_on_dispatch_bound_workloads": any(
            v["speedup"] >= 10.0 for v in out.values()
            if isinstance(v, dict) and "speedup" in v),
    }
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
