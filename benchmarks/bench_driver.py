"""Experiment-engine throughput benchmarks (driver, not kernels).

Times the device-resident chunked-``lax.scan`` driver against the legacy
one-jitted-call-per-round loop (``dispatch="per_round"``), plus the
vmapped multi-seed sweep against sequential per-round replications, at
three regimes:

* ``pool_d384`` — the paper shape (K=6 arms, d=384). The round body is
  memory-bound on the (d, K·d) LinUCB inverse here, so the scan's win is
  the dispatch+transfer overhead plus in-place carry updates.
* ``pool_d64`` — a dispatch-bound pool (d=64): per-round host round-trips
  dominate the legacy path, which is where the device-resident engine
  shines (the production regime: cheap per-decision compute, huge T).
* ``synthetic_d16`` — the Theorem-1/2 driver at its default d=16.

All timings are warm (drivers compile once via the router's cached jit
programs; the first call of each config pays it, then we measure).
Results land in the bench trajectory via ``common.save_json``.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks import common
from repro.core import env as env_mod
from repro.core import router

ROUNDS = 2000
SWEEP_SEEDS = 6


def _timed(fn) -> float:
    return common.median_secs(fn)


def _compare(run_scan, run_per_round, rounds: int) -> Dict[str, float]:
    run_scan()          # warm (compile) the scanned driver
    run_per_round()     # warm the per-round driver
    scan_s = _timed(run_scan)
    per_round_s = _timed(run_per_round)
    return {
        "per_round_s": per_round_s,
        "scan_s": scan_s,
        "per_round_rounds_per_s": rounds / per_round_s,
        "scan_rounds_per_s": rounds / scan_s,
        "speedup": per_round_s / scan_s,
    }


def _verify_equivalence(rounds: int = 96) -> bool:
    for name in router.POLICIES:
        a = router.run_pool_experiment(name, rounds=rounds, seed=7,
                                       dispatch="per_round")
        b = router.run_pool_experiment(name, rounds=rounds, seed=7,
                                       dispatch="scan")
        for field in ("arms", "rewards", "costs", "regrets", "budgets",
                      "datasets"):
            if not np.array_equal(getattr(a, field), getattr(b, field)):
                return False
    return True


def run() -> Dict:
    out: Dict[str, object] = {"rounds": ROUNDS,
                              "scan_equals_per_round": _verify_equivalence()}

    for policy in ("greedy_linucb", "budget_linucb"):
        out[f"pool_d384_{policy}"] = _compare(
            lambda: router.run_pool_experiment(policy, rounds=ROUNDS,
                                               dispatch="scan"),
            lambda: router.run_pool_experiment(policy, rounds=ROUNDS,
                                               dispatch="per_round"),
            ROUNDS)

    env64 = env_mod.CalibratedPoolEnv(dim=64)
    out["pool_d64_greedy_linucb"] = _compare(
        lambda: router.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                           env=env64, dispatch="scan"),
        lambda: router.run_pool_experiment("greedy_linucb", rounds=ROUNDS,
                                           env=env64, dispatch="per_round"),
        ROUNDS)

    out["synthetic_d16_greedy_linucb"] = _compare(
        lambda: router.run_synthetic_experiment("greedy_linucb",
                                                rounds=ROUNDS,
                                                dispatch="scan"),
        lambda: router.run_synthetic_experiment("greedy_linucb",
                                                rounds=ROUNDS,
                                                dispatch="per_round"),
        ROUNDS)

    # multi-seed replication workload: S sequential per-round experiments
    # (the only option before the engine) vs ONE vmapped scan sweep. The
    # sequential cost is S × one timed run — the replications are
    # independent and the driver is warm, so the extrapolation is exact
    # up to noise.
    seeds = list(range(SWEEP_SEEDS))
    router.run_pool_experiment_sweep("greedy_linucb", seeds, rounds=ROUNDS,
                                     env=env64)
    sweep_s = _timed(lambda: router.run_pool_experiment_sweep(
        "greedy_linucb", seeds, rounds=ROUNDS, env=env64))
    one_per_round = _timed(lambda: router.run_pool_experiment(
        "greedy_linucb", rounds=ROUNDS, env=env64, dispatch="per_round"))
    out["pool_d64_sweep6_greedy_linucb"] = {
        "seeds": SWEEP_SEEDS,
        "per_round_sequential_s": one_per_round * SWEEP_SEEDS,
        "vmapped_sweep_s": sweep_s,
        "sweep_seed_rounds_per_s": SWEEP_SEEDS * ROUNDS / sweep_s,
        "speedup": one_per_round * SWEEP_SEEDS / sweep_s,
    }

    # the theorem_regret workload: S replicated synthetic regret curves
    synth_seeds = list(range(8))
    router.run_synthetic_experiment_sweep("greedy_linucb", synth_seeds,
                                          rounds=ROUNDS)
    synth_sweep_s = _timed(lambda: router.run_synthetic_experiment_sweep(
        "greedy_linucb", synth_seeds, rounds=ROUNDS))
    synth_one_pr = _timed(lambda: router.run_synthetic_experiment(
        "greedy_linucb", rounds=ROUNDS, dispatch="per_round"))
    out["synthetic_d16_sweep8_greedy_linucb"] = {
        "seeds": len(synth_seeds),
        "per_round_sequential_s": synth_one_pr * len(synth_seeds),
        "vmapped_sweep_s": synth_sweep_s,
        "sweep_seed_rounds_per_s": len(synth_seeds) * ROUNDS / synth_sweep_s,
        "speedup": synth_one_pr * len(synth_seeds) / synth_sweep_s,
    }

    common.save_json("bench_driver", out)
    return out


def main():
    out = run()
    print("\n=== Driver throughput: scanned engine vs per-round loop ===")
    print(f"scan == per_round (all policies): "
          f"{out['scan_equals_per_round']}")
    for key, v in out.items():
        if not isinstance(v, dict):
            continue
        print(f"{key}: speedup {v['speedup']:.1f}x "
              f"(scan {v.get('scan_s', v.get('vmapped_sweep_s')):.2f}s vs "
              f"per_round {v.get('per_round_s', v.get('per_round_sequential_s')):.2f}s)")
    claims = {
        "scan_equals_per_round": bool(out["scan_equals_per_round"]),
        "scan_faster_everywhere": all(
            v["speedup"] > 1.0 for v in out.values() if isinstance(v, dict)),
        "engine_10x_on_dispatch_bound_workloads": any(
            v["speedup"] >= 10.0 for v in out.values()
            if isinstance(v, dict)),
    }
    print("claims:", claims)
    return out, claims


if __name__ == "__main__":
    main()
